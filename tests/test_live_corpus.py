"""Live corpus subsystem (DESIGN.md §17): streaming ingestion, incremental
indexing, and exact invalidation.

Parity bar: an interleaved mutation/query stream must yield rows
byte-identical to a rebuilt-from-scratch corpus + index at every mutation
point — on the oracle extractor and on the real serving engine. Around
that sit the mechanism tests: mutation-log replay digests, incremental
ExactIndex/IVFIndex maintenance invariants, bounded re-embedding under
localized edits, snapshot isolation for in-flight queries, prefix-cache
doc invalidation, and the page-pool leak regression after delete.

Property layer runs under hypothesis when available and falls back to
fixed example streams otherwise (same pattern as test_index_components).
"""
import numpy as np
import pytest

try:                                   # hypothesis is optional in the seed
    from hypothesis import given, settings, strategies as st
except ImportError:                    # image; fall back to fixed examples
    given = settings = st = None

from repro.core import Filter, Query, Session, conj
from repro.core.executor import TableSample
from repro.data.corpus import Document, make_legal_corpus, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.vector_index import ExactIndex, IVFIndex
from repro.live import (LiveCorpus, LiveRetriever, LiveSession, MutationLog,
                        edit_span_bytes, render_edit, sha_text)


# ------------------------------------------------------------- fixtures ----


def _fresh_subset(full, ids):
    """`Corpus.subset` shares Document objects with its parent, and live
    mutations land in place — copy the docs so module-scoped fixtures stay
    pristine across tests."""
    sub = full.subset(ids)
    sub.docs = {d: Document(doc.doc_id, doc.domain, doc.text, dict(doc.truth),
                            dict(doc.spans), doc.tokens, version=doc.version,
                            sha=doc.sha)
                for d, doc in sub.docs.items()}
    return sub


@pytest.fixture(scope="module")
def wiki_full():
    return make_wiki_corpus(seed=0)


@pytest.fixture(scope="module")
def wiki_ids(wiki_full):
    players = [d for d in wiki_full.docs if wiki_full.docs[d].domain == "players"]
    teams = [d for d in wiki_full.docs if wiki_full.docs[d].domain == "teams"]
    return players[:20] + teams[:8]


def _live_stack(wiki_full, wiki_ids, batch_size=8, **session_kw):
    live = LiveCorpus(_fresh_subset(wiki_full, wiki_ids))
    retr = LiveRetriever(live)
    sess = LiveSession(live, retr, OracleExtractor(live),
                       batch_size=batch_size, **session_kw)
    return live, retr, sess


def _players_query():
    return Query(tables=["players"], select=[("players", "player_name")],
                 where=conj(Filter("age", ">", 30, table="players"),
                            Filter("all_stars", ">=", 3, table="players")))


def _donor(wiki_full, live):
    return next(d for d in wiki_full.docs
                if d not in live.docs
                and wiki_full.docs[d].domain == "players")


def _rows_key(rows):
    return sorted(rows, key=repr)      # rows are dicts (incl. nested _docs)


def _oracle_rows(live, retr, query):
    """Rows from a corpus + index rebuilt from scratch at the current
    mutation point (fresh session, same seed, frozen idf clone)."""
    snap = live.snapshot()
    osess = Session(retr.rebuild_reference(snap), OracleExtractor(snap),
                    batch_size=8)
    return _rows_key(osess.execute(query).rows)


# ------------------------------------------------------ log + manifest ----


def test_mutation_log_replay_and_digests(wiki_full, wiki_ids):
    live, _retr, sess = _live_stack(wiki_full, wiki_ids)
    pid = wiki_ids[0]
    sess.update(pid, render_edit(live, pid, "age", 41))
    sess.delete(wiki_ids[1])
    sess.ingest("players/new0", wiki_full.docs[_donor(wiki_full, live)].text,
                "players")
    assert live.seq == 3
    # every doc carries (version, sha) matching the manifest
    for doc_id, doc in live.docs.items():
        assert live.log.manifest[doc_id] == (doc.version, doc.sha)
        assert doc.sha == sha_text(doc.text)
    # serialization round-trip preserves the stream digest (the manifest
    # additionally carries seed-corpus entries a bare log can't know)
    rt = MutationLog.from_jsonl(live.log.to_jsonl())
    assert rt.digest() == live.log.digest()
    # replay against a fresh seed snapshot reproduces the manifest exactly
    fresh = LiveCorpus(_fresh_subset(wiki_full, wiki_ids))
    live.log.replay(fresh)
    assert fresh.log.digest() == live.log.digest()
    assert fresh.log.manifest_digest() == live.log.manifest_digest()
    assert {d: doc.text for d, doc in fresh.docs.items()} == \
           {d: doc.text for d, doc in live.docs.items()}


def test_edit_span_bytes_localized():
    assert edit_span_bytes("abc def ghi", "abc xyz ghi") == 3
    assert edit_span_bytes("same", "same") == 0
    assert edit_span_bytes("abc", "abcdef") == 3
    # pure deletion counts no new bytes
    assert edit_span_bytes("abc def ghi", "abc ghi") == 0


# ------------------------------------------- incremental index invariants --


def _norm_rows(rng, n, d=16):
    e = rng.normal(size=(n, d)).astype(np.float32)
    return e / np.linalg.norm(e, axis=-1, keepdims=True)


def _l2(a, b):
    return float(np.sqrt(max(((a - b) ** 2).sum(), 0.0)))


def _check_index_maintenance(make, seed, n0, ops):
    """Interleaved add/remove on an incremental index vs the surviving-row
    ground truth: same length, same live ids, search never returns a
    tombstoned id, and the tombstone count respects the compaction bound
    after every op."""
    rng = np.random.default_rng(seed)
    emb = _norm_rows(rng, n0)
    ids = [f"d{i}" for i in range(n0)]
    idx = make(emb.copy(), list(ids))
    alive = dict(zip(ids, emb))
    next_id = n0
    for kind in ops:
        if kind == "add" or len(alive) <= 2:
            row = _norm_rows(rng, 1)[0]
            nid = f"d{next_id}"
            next_id += 1
            idx.add(row[None], [nid])
            alive[nid] = row
        else:
            victim = sorted(alive)[int(rng.integers(len(alive)))]
            idx.remove([victim])
            del alive[victim]
        assert len(idx) == len(alive)
        assert sorted(idx.live_ids()) == sorted(alive)
        assert idx.n_tombstones <= idx.compact_ratio * len(idx.ids) + 1
        q = _norm_rows(rng, 1)[0]
        (got_ids, got_d), = idx.search(q, k=min(5, len(alive)))
        assert all(g in alive for g in got_ids)
        assert got_d == sorted(got_d)
        # range search agrees with a brute-force scan of the live rows
        r_ids, _ = idx.range_search(q, 1.0)
        brute = {k for k, v in alive.items() if _l2(v, q) < 1.0}
        if isinstance(idx, ExactIndex):
            assert set(r_ids) == brute
        else:                          # IVF: approximate, but never dead
            assert set(r_ids) <= set(alive)
        # distance() resolves the live occurrence even after re-adds
        some = sorted(alive)[0]
        assert abs(idx.distance(q, some) - _l2(alive[some], q)) < 1e-5


_STREAMS = [(0, 12, ["add", "rm", "rm", "add", "rm", "add"]),
            (1, 8, ["rm"] * 6 + ["add"] * 3),
            (2, 20, ["add", "add", "rm", "rm", "rm", "rm", "rm", "add"])]


@pytest.mark.parametrize("seed,n0,ops", _STREAMS)
def test_exact_index_incremental_maintenance(seed, n0, ops):
    _check_index_maintenance(ExactIndex, seed, n0, ops)


@pytest.mark.parametrize("seed,n0,ops", _STREAMS)
def test_ivf_index_incremental_maintenance(seed, n0, ops):
    def make(emb, ids):
        return IVFIndex(emb, ids, n_lists=4, nprobe=4, seed=0)
    _check_index_maintenance(make, seed, n0, ops)


def test_ivf_recluster_is_per_list_not_global():
    """Churn concentrated in one region re-clusters a bounded number of
    lists; untouched lists keep their centers (never a global k-means)."""
    rng = np.random.default_rng(3)
    emb = _norm_rows(rng, 64)
    idx = IVFIndex(emb.copy(), list(range(64)), n_lists=8, nprobe=8, seed=0)
    centers0 = idx.centers.copy()
    # remove most members of one list to push its churn over the ratio
    target = max(range(len(idx.lists)), key=lambda li: len(idx.lists[li]))
    victims = [idx.ids[int(r)] for r in idx.lists[target]][:-1]
    idx.remove(victims)
    assert idx.maint_stats["reclustered_lists"] >= 1
    untouched = [li for li in range(len(idx.lists))
                 if li != target and not idx._churn[li]]
    assert untouched
    for li in untouched:
        assert np.allclose(idx.centers[li], centers0[li])


# --------------------------------------------------- incremental retriever --


def _retriever_parity(live, retr):
    """Doc-level candidates and per-doc segment hits of the live retriever
    match a from-scratch rebuild under the frozen idf clone."""
    ref = retr.rebuild_reference()
    assert retr.candidate_docs("players", ["age"]) == \
        ref.candidate_docs("players", ["age"])
    for doc_id in list(live.docs)[:6]:
        assert retr.segments(doc_id, "age", "players") == \
            ref.segments(doc_id, "age", "players")


def test_live_retriever_matches_rebuild_across_mutations(wiki_full, wiki_ids):
    live = LiveCorpus(_fresh_subset(wiki_full, wiki_ids))
    retr = LiveRetriever(live)
    _retriever_parity(live, retr)
    pid = wiki_ids[2]
    live.update(pid, render_edit(live, pid, "age", 44))
    _retriever_parity(live, retr)
    live.delete(wiki_ids[3])
    _retriever_parity(live, retr)
    live.ingest("players/new1", wiki_full.docs[_donor(wiki_full, live)].text,
                "players")
    _retriever_parity(live, retr)
    assert len(retr.doc_index) == len(live.docs)


def test_reembedded_bytes_bounded_by_edit_locality():
    """Acceptance metric: a localized edit on a long document re-embeds a
    bounded slice of the corpus — far below the document, and orders of
    magnitude below the full-rebuild embedding cost the static path pays."""
    full = make_legal_corpus(seed=1)
    ids = sorted(full.docs)[:6]
    live = LiveCorpus(_fresh_subset(full, ids))
    retr = LiveRetriever(live)
    emb = retr.embedder
    build_bytes = emb.reembedded_bytes          # full-rebuild contrast figure
    doc_id = ids[0]
    attr = next(iter(live.docs[doc_id].spans))
    emb.reset_counters()
    live.update(doc_id, render_edit(live, doc_id, attr, 424243))
    edited = live.stats.edited_bytes
    doc_bytes = len(live.docs[doc_id].text.encode("utf-8"))
    assert 0 < edited < 64                       # the edit is localized
    assert emb.reembedded_bytes < 0.5 * doc_bytes
    assert emb.reembedded_bytes < 0.1 * build_bytes
    assert emb.reused_bytes > emb.reembedded_bytes


# ----------------------------------------------------- end-to-end parity ---


def test_interleaved_stream_matches_rebuild_oracle(wiki_full, wiki_ids):
    """THE parity bar: ingest/update/delete interleaved with queries gives
    rows byte-identical to a rebuilt-from-scratch corpus/index at every
    mutation point."""
    live, retr, sess = _live_stack(wiki_full, wiki_ids)
    q = _players_query()
    assert _rows_key(sess.execute(q).rows) == _oracle_rows(live, retr, q)

    pid = wiki_ids[0]
    rec = sess.update(pid, render_edit(live, pid, "age", 99))
    assert rec is not None and live.docs[pid].truth["age"] == 99
    assert _rows_key(sess.execute(q).rows) == _oracle_rows(live, retr, q)

    sess.delete(wiki_ids[1])
    assert _rows_key(sess.execute(q).rows) == _oracle_rows(live, retr, q)

    sess.ingest("players/new2", wiki_full.docs[_donor(wiki_full, live)].text,
                "players")
    assert _rows_key(sess.execute(q).rows) == _oracle_rows(live, retr, q)

    cs = sess.cascade.stats
    assert cs.mutations == 3
    assert cs.samples_dropped >= 3               # exact policy: every table
    assert sess.live_stats["mutations_applied"] == 3


def test_cache_invalidation_is_exact(wiki_full, wiki_ids):
    """Only the mutated doc's cache/escalation entries drop; every other
    document's investment survives (their values are byte-identical to
    fresh extraction, so retention is row-invisible)."""
    live, _retr, sess = _live_stack(wiki_full, wiki_ids)
    sess.execute(_players_query())
    before = dict(sess.cache)
    pid = next(k[0] for k in before
               if live.docs.get(k[0]) is not None
               and "age" in live.docs[k[0]].spans)
    mine = [k for k in before if k[0] == pid]
    others = {k: v for k, v in before.items() if k[0] != pid}
    sess.update(pid, render_edit(live, pid, "age", 55))
    assert all(k not in sess.cache for k in mine)
    assert all(sess.cache.get(k) == v for k, v in others.items())
    assert sess.cascade.stats.cache_entries_dropped == len(mine)


def test_sample_version_stamping_and_exact_drop(wiki_full, wiki_ids):
    live, _retr, sess = _live_stack(wiki_full, wiki_ids)
    q = _players_query()
    sess.execute(q)
    sample = sess._samples["players"]
    assert isinstance(sample, TableSample) and sample.version == live.seq
    pid = wiki_ids[4]
    sess.update(pid, render_edit(live, pid, "age", 48))
    assert "players" not in sess._samples        # exact policy drops it
    sess.execute(q)
    assert sess._samples["players"].version == live.seq


def test_sampled_only_policy_retains_unaffected_samples(wiki_full, wiki_ids):
    live, _retr, sess = _live_stack(wiki_full, wiki_ids,
                                    sample_policy="sampled_only")
    sess.execute(_players_query())
    sample = sess._samples["players"]
    in_sample = set(sample.sampled)
    unsampled = next(d for d in live.docs if d not in in_sample)
    sess.update(unsampled, live.docs[unsampled].text + " (edited)")
    assert sess._samples.get("players") is sample    # retained
    assert sess.cascade.stats.samples_retained >= 1
    hit = sample.sampled[0]
    sess.update(hit, live.docs[hit].text + " (edited)")
    assert "players" not in sess._samples            # directly stale: drops


# ------------------------------------------------------ snapshot isolation --


def test_mutation_defers_behind_row_emitting_query(wiki_full, wiki_ids):
    """A query that has emitted rows finishes on the pre-mutation snapshot;
    the mutation applies once it drains — rows are never torn."""
    live, _retr, sess = _live_stack(wiki_full, wiki_ids, batch_size=2)
    h = sess.submit(_players_query())
    while not h._rows and h in sess._active:
        sess._step()
    assert h._rows and h in sess._active, "rows stream mid-flight"
    pid = wiki_ids[0]
    pre_rows = list(h._rows)
    rec = sess.update(pid, render_edit(live, pid, "age", 99))
    assert rec is None and live.seq == 0             # deferred, not applied
    assert sess.live_stats["mutations_deferred"] >= 1
    res = h.result()
    assert res.rows[:len(pre_rows)] == pre_rows      # emitted rows stand
    sess._apply_pending()
    assert live.seq == 1 and live.docs[pid].truth["age"] == 99
    assert sess.live_stats["mutations_applied"] == 1


def test_mutation_restarts_rowless_inflight_query(wiki_full, wiki_ids):
    """An in-flight query with no emitted rows restarts and runs entirely
    on the post-mutation snapshot — identical to submitting it after the
    mutation."""
    live, retr, sess = _live_stack(wiki_full, wiki_ids)
    q = _players_query()
    h = sess.submit(q)
    sess._step()                                     # in flight, no rows yet
    assert not h._rows and h in sess._active
    pid = wiki_ids[0]
    rec = sess.update(pid, render_edit(live, pid, "age", 99))
    assert rec is not None and sess.live_stats["query_restarts"] >= 1
    assert _rows_key(h.result().rows) == _oracle_rows(live, retr, q)


# -------------------------------------------------------- property stream --


def _run_stream(seed, ops):
    """Random interleaved mutation stream vs rebuild oracle: index sizes,
    tombstone bounds, retrieval parity, cache exactness, and replay
    digests at every step."""
    full = make_wiki_corpus(seed=0)
    players = [d for d in full.docs if full.docs[d].domain == "players"]
    ids = players[:10]
    live = LiveCorpus(_fresh_subset(full, ids))
    retr = LiveRetriever(live)
    rng = np.random.default_rng(seed)
    donors = iter(players[10:10 + len(ops)])
    cache = {(d, "age"): live.docs[d].truth.get("age") for d in ids}
    n_new = 0
    for kind in ops:
        pool = sorted(live.docs)
        if kind == "update":
            doc = pool[int(rng.integers(len(pool)))]
            try:
                text = render_edit(live, doc, "age",
                                   int(rng.integers(18, 45)))
            except (KeyError, ValueError):
                continue               # doc lost its age span: skip
            live.update(doc, text)
            cache.pop((doc, "age"), None)
        elif kind == "delete" and len(pool) > 2:
            doc = pool[int(rng.integers(len(pool)))]
            live.delete(doc)
            cache.pop((doc, "age"), None)
        else:
            donor = next(donors, None)
            if donor is None:
                continue
            n_new += 1
            live.ingest(f"players/p{n_new}", full.docs[donor].text,
                        "players")
        # index invariants at every step
        di = retr.doc_index
        assert len(di) == len(live.docs)
        assert sorted(di.live_ids()) == sorted(live.docs)
        assert di.n_tombstones <= di.compact_ratio * len(di.ids) + 1
        # unchanged cache entries still match ground truth exactly
        for (d, a), v in cache.items():
            assert live.docs[d].truth.get(a) == v
    # final retrieval parity vs rebuilt-from-scratch
    ref = retr.rebuild_reference()
    assert retr.candidate_docs("players", ["age"]) == \
        ref.candidate_docs("players", ["age"])
    for doc_id in sorted(live.docs)[:4]:
        assert retr.segments(doc_id, "age", "players") == \
            ref.segments(doc_id, "age", "players")
    # replay digest: the recorded stream reproduces the manifest
    fresh = LiveCorpus(_fresh_subset(full, ids))
    live.log.replay(fresh)
    assert fresh.log.manifest_digest() == live.log.manifest_digest()


if st is not None:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6),
           st.lists(st.sampled_from(["ingest", "update", "delete"]),
                    min_size=1, max_size=5))
    def test_random_streams_match_rebuild(seed, ops):
        _run_stream(seed, ops)
else:
    @pytest.mark.parametrize("seed,ops", [
        (0, ["update", "delete", "ingest"]),
        (1, ["delete", "delete", "update", "ingest", "update"]),
        (2, ["ingest", "update", "update", "delete"])])
    def test_random_streams_match_rebuild(seed, ops):
        _run_stream(seed, ops)


# ------------------------------------------------------------ served path --


def _served_stack(live, *, paged=False, max_len=1024, **ext_kw):
    import jax
    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.extract.served import ServedExtractor
    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    kw = dict(slots=2, max_len=max_len, prefix_cache=True)
    if paged:
        kw.update(kv_layout="paged", page_size=16)
    eng = ServingEngine(cfg, params, **kw)
    ext = ServedExtractor(live, eng, max_new=4, **ext_kw)
    return (cfg, params, kw), eng, ext


def _mini_swde(n=6):
    from repro.data.corpus import make_swde_corpus
    full = make_swde_corpus()
    ids = [d for d in sorted(full.docs) if "universities" in d][:n]
    return full, ids


def test_served_interleaved_parity_and_prefix_invalidation():
    """Served leg of the parity bar: one update between queries on the
    real engine still byte-matches the rebuilt oracle."""
    from repro.extract.served import ServedExtractor
    from repro.serving.engine import ServingEngine

    full, ids = _mini_swde()
    live = LiveCorpus(_fresh_subset(full, ids))
    retr = LiveRetriever(live)
    (cfg, params, kw), eng, ext = _served_stack(live)
    sess = LiveSession(live, retr, ext, batch_size=2)
    assert eng.prefix_cache in sess.cascade.prefix_caches
    q = Query(tables=["universities"],
              select=[("universities", "university_name")],
              where=Filter("tuition", "<", 30000, table="universities"))

    def oracle():
        snap = live.snapshot()
        oeng = ServingEngine(cfg, params, **kw)
        osess = Session(retr.rebuild_reference(snap),
                        ServedExtractor(snap, oeng, max_new=4), batch_size=2)
        return _rows_key(osess.execute(q).rows)

    assert _rows_key(sess.execute(q).rows) == oracle()
    doc = ids[0]
    sess.update(doc, render_edit(live, doc, "tuition", 12000))
    assert _rows_key(sess.execute(q).rows) == oracle()


def test_delete_releases_cached_prefix_pages():
    """Leak regression: after delete() of a doc whose doc-first escalation
    prefix was cached in the paged pool, the allocator's free-page count
    returns to its pre-insert baseline."""
    full, ids = _mini_swde()
    live = LiveCorpus(_fresh_subset(full, ids))
    retr = LiveRetriever(live)
    _c, eng, ext = _served_stack(live, paged=True, max_len=512,
                                 doc_prefix_escalation=True)
    sess = LiveSession(live, retr, ext, batch_size=2)
    free0 = eng.pool_free_pages()
    doc = ids[0]
    text = live.docs[doc].text[:200]
    ext.escalate_batch([(doc, "tuition", [text]),
                        (doc, "university_name", [text])])
    pc = eng.prefix_cache
    assert any(doc in e.doc_ids for e in pc._entries.values())
    assert eng.pool_free_pages() < free0          # entry holds page refs
    assert pc.stats.hits >= 1                     # attrs shared the doc prefix
    sess.delete(doc)
    assert pc.stats.invalidated_entries >= 1
    assert eng.pool_free_pages() == free0         # every page returned


def test_template_prefixes_survive_mutation():
    """extract_batch prefixes are template-only (content rides in the
    tail): a doc mutation must NOT invalidate them."""
    full, ids = _mini_swde()
    live = LiveCorpus(_fresh_subset(full, ids))
    retr = LiveRetriever(live)
    _c, eng, ext = _served_stack(live)
    sess = LiveSession(live, retr, ext, batch_size=2)
    doc = ids[0]
    ext.extract_batch([(doc, "tuition", [live.docs[doc].text[:120]])])
    n0 = len(eng.prefix_cache)
    assert n0 >= 1
    sess.update(doc, render_edit(live, doc, "tuition", 21000))
    assert len(eng.prefix_cache) == n0
    assert eng.prefix_cache.stats.invalidated_entries == 0
