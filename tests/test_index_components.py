"""Unit tests for the index substrate: embedder, segmenter, vector indexes,
k-means, thresholds and retrieval modes; plus hypothesis properties on the
vector-index contract.
"""
import numpy as np
import pytest

try:                                   # hypothesis is optional in the seed
    from hypothesis import given, settings, strategies as st
except ImportError:                    # image; fall back to fixed examples
    given = settings = st = None

from repro.data.corpus import make_wiki_corpus
from repro.data.tokens import count_tokens, split_sentences
from repro.index.embedder import HashedEmbedder
from repro.index.kmeans import kmeans
from repro.index.retriever import TwoLevelRetriever
from repro.index.segmenter import key_sentences, segment_document
from repro.index.vector_index import ExactIndex, IVFIndex


def test_embedder_deterministic_and_normalized():
    e = HashedEmbedder()
    a = e.embed(["the cat sat on the mat", "a completely different sentence"])
    b = e.embed(["the cat sat on the mat", "a completely different sentence"])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(np.linalg.norm(a, axis=1), 1.0, atol=1e-5)
    # similar sentences are closer than dissimilar ones
    sim = e.embed(["the cat sat on the mat", "the cat sat on a mat",
                   "quarterly revenue guidance was revised upward"])
    d_close = np.linalg.norm(sim[0] - sim[1])
    d_far = np.linalg.norm(sim[0] - sim[2])
    assert d_close < d_far


def test_segmenter_covers_text():
    text = ("First point about apples. Second point about apples. "
            "Now trains are different. Trains run on tracks. "
            "Finally, a word on cheese.")
    segs = segment_document("d", text, HashedEmbedder())
    joined = " ".join(s.text for s in segs)
    for sent in split_sentences(text):
        assert sent in joined
    assert all(s.tokens == count_tokens(s.text) for s in segs)


def test_key_sentences_keeps_lead():
    text = " ".join([f"Sentence number {i} mentions value {i*7}." for i in range(20)])
    summary = key_sentences(text, max_sentences=5)
    assert "Sentence number 0" in summary
    assert count_tokens(summary) < count_tokens(text)


def _maybe_property(fn):
    """Run under hypothesis when available, else over fixed examples."""
    if st is not None:
        return settings(max_examples=25, deadline=None)(
            given(st.integers(min_value=1, max_value=40),
                  st.integers(min_value=1, max_value=8),
                  st.integers(min_value=0, max_value=10**6))(fn))
    return pytest.mark.parametrize(
        "n,k,seed", [(1, 1, 0), (7, 3, 1), (17, 8, 2), (40, 5, 3)])(fn)


@_maybe_property
def test_exact_index_topk_property(n, k, seed):
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, 16)).astype(np.float32)
    idx = ExactIndex(emb)
    q = rng.normal(size=(16,)).astype(np.float32)
    (ids, dists), = idx.search(q, min(k, n))
    brute = np.sqrt(((emb - q) ** 2).sum(-1))
    want = np.sort(brute)[: len(ids)]
    np.testing.assert_allclose(sorted(dists), want, rtol=1e-4, atol=1e-4)
    # range search consistent with distances
    tau = float(np.median(brute))
    rids, rd = idx.range_search(q, tau)
    assert set(rids) == {i for i, d in enumerate(brute) if d < tau}
    # batched range search agrees with the serial one per query
    taus = [tau, tau * 0.5]
    many = idx.range_search_many(np.stack([q, q]), taus)
    for (mids, mds), t in zip(many, taus):
        sids, sds = idx.range_search(q, t)
        assert mids == sids
        np.testing.assert_allclose(mds, sds, rtol=1e-5, atol=1e-5)


def test_ivf_recall_reasonable():
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(512, 32)).astype(np.float32)
    exact = ExactIndex(emb)
    ivf = IVFIndex(emb, n_lists=16, nprobe=6)
    hits = 0
    for i in range(20):
        q = rng.normal(size=(32,)).astype(np.float32)
        (eids, _), = exact.search(q, 5)
        (aids, _), = ivf.search(q, 5)
        hits += len(set(eids) & set(aids))
    assert hits / (20 * 5) >= 0.6        # nprobe=6/16 should recall most


def test_ivf_batched_range_search_matches_serial():
    """`range_search_many` is the batched-retrieval API the scheduler's
    `prefetch_segments` drives; IVF must answer it identically to a loop of
    serial `range_search` calls (regression: it used to be missing)."""
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(400, 16)).astype(np.float32)
    ivf = IVFIndex(emb, n_lists=8, nprobe=3)
    qs = rng.normal(size=(5, 16)).astype(np.float32)
    taus = [2.0, 3.5, 5.0, 1.0, 4.2]
    many = ivf.range_search_many(qs, taus)
    for (mids, mds), q, tau in zip(many, qs, taus):
        sids, sds = ivf.range_search(q, tau)
        assert mids == sids
        np.testing.assert_allclose(mds, sds, rtol=1e-5, atol=1e-5)
        # distances really honour the threshold and come back sorted
        assert all(d < tau for d in mds)
        assert mds == sorted(mds)


def test_ivf_full_probe_equals_exact_range_search():
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(200, 16)).astype(np.float32)
    exact = ExactIndex(emb)
    ivf = IVFIndex(emb, n_lists=8, nprobe=8)     # probe everything
    q = rng.normal(size=(16,)).astype(np.float32)
    eids, _ = exact.range_search(q, 3.0)
    aids, _ = ivf.range_search(q, 3.0)
    assert set(aids) == set(eids)


def test_ivf_recall_improves_with_nprobe():
    rng = np.random.default_rng(4)
    emb = rng.normal(size=(512, 24)).astype(np.float32)
    exact = ExactIndex(emb)
    qs = rng.normal(size=(15, 24)).astype(np.float32)

    def recall(nprobe):
        ivf = IVFIndex(emb, n_lists=16, nprobe=nprobe)
        hit = 0
        for q in qs:
            (eids, _), = exact.search(q, 5)
            (aids, _), = ivf.search(q, 5)
            hit += len(set(eids) & set(aids))
        return hit / (len(qs) * 5)

    r1, r4, r16 = recall(1), recall(4), recall(16)
    assert r1 <= r4 + 1e-9 <= r16 + 2e-9
    assert r16 == 1.0                            # full probe == exact


def test_retriever_selects_ivf_at_scale():
    """Above `approx_threshold` vectors the retriever backs its stores with
    IVF (regression: it hardcoded ExactIndex, so any batched caller crashed
    at corpus scale); below it, exact stays the default."""
    corpus = make_wiki_corpus(0)
    small = TwoLevelRetriever(corpus)
    assert isinstance(small.doc_index, ExactIndex)
    approx = TwoLevelRetriever(corpus, approx_threshold=1,
                               ivf_n_lists=4, ivf_nprobe=4)
    assert isinstance(approx.doc_index, IVFIndex)
    assert all(isinstance(ix, IVFIndex) for ix in approx.seg_index.values())
    # the whole retrieval surface works on the approximate store,
    # including the batched prefetch path
    docs = approx.candidate_docs("players", ["age"])
    assert docs
    pairs = [(docs[0], "age", "players"), (docs[0], "ppg", "players")]
    approx.prefetch_segments(pairs)
    segs = approx.segments(docs[0], "age", "players")
    assert isinstance(segs, list)
    # nprobe == n_lists probes every list -> identical hits to exact
    exact_segs = small.segments(docs[0], "age", "players")
    assert segs == exact_segs
    # the "rank, no filter" modes must still return EVERY table document
    # when the doc store is approximate (IVF probes a subset of lists)
    rag = TwoLevelRetriever(corpus, mode="rag_topk", approx_threshold=1,
                            ivf_n_lists=8, ivf_nprobe=1)
    ranked = rag.candidate_docs("players", ["age"])
    assert set(ranked) == set(corpus.tables["players"])


def test_kmeans_clusters_separate_data():
    rng = np.random.default_rng(1)
    a = rng.normal(loc=0.0, size=(50, 8))
    b = rng.normal(loc=6.0, size=(50, 8))
    x = np.concatenate([a, b]).astype(np.float32)
    centers, assign = kmeans(x, 2, seed=3)
    assert len(set(assign[:50])) == 1 and len(set(assign[50:])) == 1
    assert assign[0] != assign[50]


def test_retriever_fork_isolated():
    corpus = make_wiki_corpus(0)
    base = TwoLevelRetriever(corpus)
    f1 = base.fork()
    f1.add_evidence("players", "age", ["He is 31 years old."])
    assert not base._attr_state
    f2 = base.fork()
    assert not f2._attr_state


def test_retrieval_modes_contract():
    corpus = make_wiki_corpus(0)
    for mode in ("quest", "segment_only", "no_evidence", "llm_evidence",
                 "rag_topk", "fulldoc"):
        r = TwoLevelRetriever(corpus, mode=mode)
        docs = r.candidate_docs("players", ["age"])
        assert docs, mode
        segs = r.segments(docs[0], "age", "players")
        assert isinstance(segs, list)
        if mode == "fulldoc":
            assert segs[0] == corpus.docs[docs[0]].text
        assert r.segment_tokens(docs[0], "age", "players") == \
            sum(count_tokens(s) for s in segs)
