"""Property-based tests for QUEST's optimizer math (paper §3).

- Lemma 1 / Eq. 5 / Eq. 6: `plan_expression`'s sort-based order achieves the
  brute-force minimum expected cost over all orders within the tree
  structure, for arbitrary costs/selectivities and arbitrary AND/OR trees.
- Cost-model identities: node probability composition, order-invariance of
  the weight terms.
- Lemma 2: the join-transformation plans (2)/(3) never cost more than the
  classical Plan (1) under the paper's cost model.
"""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.expr import And, Filter, Or
from repro.core.ordering import (exhaustive_plan, plan_expression,
                                 plan_fixed_order)

probs = st.floats(min_value=0.01, max_value=0.99)
costs = st.floats(min_value=0.1, max_value=1000.0)


@st.composite
def expr_trees(draw, max_depth=2, max_children=3):
    """Random AND/OR trees with per-filter (cost, selectivity) annotations."""
    counter = draw(st.integers(min_value=0, max_value=10**6))
    annotations = {}

    def build(depth, idx=[0]):
        if depth == 0 or draw(st.booleans()):
            name = f"a{idx[0]}"
            idx[0] += 1
            annotations[name] = (draw(costs), draw(probs))
            return Filter(name, ">", 0)
        n = draw(st.integers(min_value=2, max_value=max_children))
        kids = tuple(build(depth - 1, idx) for _ in range(n))
        return (And if draw(st.booleans()) else Or)(kids)

    root = build(max_depth)
    if isinstance(root, Filter):  # ensure at least one internal node
        other = build(0)
        root = And((root, other))
    return root, annotations


@given(expr_trees())
@settings(max_examples=60, deadline=None)
def test_plan_matches_exhaustive_optimum(tree_ann):
    tree, ann = tree_ann
    cost_fn = lambda f: ann[f.attr][0]
    sel_fn = lambda f: ann[f.attr][1]
    fast = plan_expression(tree, cost_fn, sel_fn)
    brute = exhaustive_plan(tree, cost_fn, sel_fn)
    assert fast.cost == pytest.approx(brute.cost, rel=1e-9), (
        fast.describe(), brute.describe())
    assert fast.prob == pytest.approx(brute.prob, rel=1e-9)


@given(expr_trees())
@settings(max_examples=40, deadline=None)
def test_plan_beats_or_ties_any_fixed_order(tree_ann):
    tree, ann = tree_ann
    cost_fn = lambda f: ann[f.attr][0]
    sel_fn = lambda f: ann[f.attr][1]
    fast = plan_expression(tree, cost_fn, sel_fn)
    for key in (lambda n: n.prob, lambda n: -n.prob, lambda n: n.cost,
                lambda n: hash(id(n)) % 97):
        other = plan_fixed_order(tree, cost_fn, sel_fn, key_fn=key)
        assert fast.cost <= other.cost + 1e-9


@given(st.lists(st.tuples(costs, probs), min_size=1, max_size=8))
@settings(max_examples=100, deadline=None)
def test_conjunction_cost_formula(items):
    """Expected cost identity: sum_i c_i * prod_{j<i} p_j (Eq. 2 first term)."""
    fs = tuple(Filter(f"a{i}", ">", 0) for i in range(len(items)))
    tree = And(fs) if len(fs) > 1 else fs[0]
    plan = plan_expression(tree, lambda f: items[int(f.attr[1:])][0],
                           lambda f: items[int(f.attr[1:])][1])
    order = plan.ordered_filters()
    exp_cost, reach, prob_all = 0.0, 1.0, 1.0
    for f in order:
        c, p = items[int(f.attr[1:])]
        exp_cost += c * reach
        reach *= p
        prob_all *= p
    assert plan.cost == pytest.approx(exp_cost, rel=1e-9)
    assert plan.prob == pytest.approx(prob_all, rel=1e-9)
    # Lemma 1: descending (1-p)/c
    keys = [(1 - items[int(f.attr[1:])][1]) / items[int(f.attr[1:])][0] for f in order]
    assert keys == sorted(keys, reverse=True)


# ------------------------------------------------------------- Lemma 2 -----


@given(
    st.integers(min_value=1, max_value=40),   # |T1|
    st.integers(min_value=1, max_value=40),   # |T2|
    costs, costs,                             # filter cost per doc c1, c2
    costs, costs,                             # join-attr cost ca, ca'
    probs, probs,                             # filter selectivities p1, p2
    probs,                                    # IN-filter selectivity p_in
)
@settings(max_examples=300, deadline=None)
def test_join_transform_never_worse_than_plan1(n1, n2, c1, c2, ca, cap, p1, p2, p_in):
    """Paper Lemma 2 under the §3.2.1 cost model (uniform per-doc costs).

    Plan 1: run filters on both tables, extract join attrs of survivors.
    Plan 2: run T1's filters, extract its join attr, then on T2 order the
            IN filter with T2's filter optimally (plan_expression).
    """
    plan1 = n1 * c1 + p1 * n1 * ca + n2 * c2 + p2 * n2 * cap

    in_f = Filter("join", "in", frozenset({1}))
    f2 = Filter("f2", ">", 0)
    t2_expr = And((in_f, f2))
    cost_fn = lambda f: cap if f.attr == "join" else c2
    sel_fn = lambda f: p_in if f.attr == "join" else p2
    t2_cost = plan_expression(t2_expr, cost_fn, sel_fn).cost
    plan2 = n1 * c1 + p1 * n1 * ca + n2 * t2_cost

    # plan1's T2-side expects cost c2 + p2*cap per doc; plan2's optimal order
    # can only improve on any fixed order, including [f2 then join-extract]:
    assert plan2 <= plan1 + 1e-6 * max(plan1, 1.0)
