"""Distributed behaviour on a small fake-device mesh (subprocess: the device
count must be set before jax initializes, so these run in children).

Covers: sharded train step == single-device train step (GSPMD correctness),
elastic restore (checkpoint from mesh A restored on mesh B), pod-axis int8
gradient compression convergence parity, sharding-rule sanity, and a reduced
dry-run (lower+compile) smoke.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_child(code: str, devices: int = 8, timeout: int = 420):
    prog = textwrap.dedent(code)
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    return res.stdout


def test_sharded_train_matches_single_device():
    out = run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.training.train_step import make_train_step
    from repro.training.optim import OptConfig
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import sharding as sh
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_smoke_config("qwen3-32b").replace(n_kv_heads=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    init_fn, step = make_train_step(cfg, opt)
    st = init_fn(params)
    p1, s1, m1 = jax.jit(step)(params, st, batch)

    mesh = make_test_mesh(2, 4)
    pshard = sh.param_shardings(cfg, params, mesh)
    params_sh = jax.device_put(params, pshard)
    batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    st_sh = init_fn(params_sh)
    constrain = sh.make_constrain(mesh, 8)
    _, step_sh = make_train_step(cfg, opt, constrain=constrain)
    p2, s2, m2 = jax.jit(step_sh)(params_sh, st_sh, batch_sh)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    print("SHARDED-MATCH-OK")
    """)
    assert "SHARDED-MATCH-OK" in out


def test_elastic_restore_across_meshes():
    out = run_child("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.configs import get_smoke_config
    from repro.models import init_params, forward
    from repro.training.checkpoint import save_checkpoint, restore_checkpoint
    from repro.launch.mesh import make_test_mesh
    from repro.distributed import sharding as sh

    cfg = get_smoke_config("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh_a = make_test_mesh(4, 2)
    params_a = jax.device_put(params, sh.param_shardings(cfg, params, mesh_a))
    d = tempfile.mkdtemp()
    save_checkpoint(d, 3, {"params": params_a}, extra={"step": 3})

    mesh_b = make_test_mesh(2, 2)   # "cluster shrank": re-shard on restore
    shard_b = {"params": sh.param_shardings(cfg, params, mesh_b)}
    tree, extra = restore_checkpoint(d, 3, {"params": params}, shardings=shard_b)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab_size}
    l1, _ = forward(cfg, params, batch)
    l2, _ = forward(cfg, tree["params"], batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    print("ELASTIC-OK", extra["step"])
    """)
    assert "ELASTIC-OK 3" in out


def test_pod_grad_compression_parity():
    out = run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import compressed_pod_mean
    from repro.distributed.sharding import shard_map
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(2, 1, multi_pod=True)   # (pod=2, data=2, model=1)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 512)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (8,))}

    def sync(grads):
        mean, resid = compressed_pod_mean(grads, "pod")
        return mean

    specs = {"w": P("pod", None), "b": P()}
    out_specs = {"w": P("pod", None), "b": P()}
    fn = jax.jit(shard_map(sync, mesh=mesh,
                           in_specs=(specs,), out_specs=out_specs,
                           check_vma=False))
    gw = jax.device_put(g["w"], NamedSharding(mesh, P("pod", None)))
    res = fn({"w": gw, "b": g["b"]})
    # exact mean across pods, within int8 quantization error
    want = (np.asarray(gw)[0] + np.asarray(gw)[1]) / 2
    got = np.asarray(res["w"])
    err = np.abs(got[0] - want).max()
    scale = np.abs(np.asarray(gw)).max() / 127
    assert err <= 2.1 * scale, (err, scale)
    np.testing.assert_allclose(got[0], got[1], atol=1e-7)  # pods agree
    print("COMPRESS-OK", float(err))
    """)
    assert "COMPRESS-OK" in out


def test_seq_sharded_decode_matches_reference():
    out = run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.distributed.decode import make_seq_sharded_decode_attn
    from repro.models.layers import decode_attention
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_test_mesh(2, 4)
    B, S, Hkv, G, hd = 4, 64, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, Hkv, G, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd))
    length = jnp.asarray([17, 64, 33, 1], jnp.int32)
    want = decode_attention(q, kc, vc, length)
    attn = make_seq_sharded_decode_attn(mesh)
    kc_s = jax.device_put(kc, NamedSharding(mesh, P("data", "model", None, None)))
    vc_s = jax.device_put(vc, NamedSharding(mesh, P("data", "model", None, None)))
    got = jax.jit(lambda q, k, v, l: attn(q, k, v, l))(q, kc_s, vc_s, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    print("SEQ-DECODE-OK")
    """)
    assert "SEQ-DECODE-OK" in out


def test_reduced_dryrun_decode():
    out = run_child("""
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import abstract_params, abstract_cache
    from repro.distributed import sharding as sh
    from repro.models import decode_step
    from jax.sharding import NamedSharding, PartitionSpec as P

    for arch in ("qwen2.5-3b", "falcon-mamba-7b", "zamba2-2.7b"):
        cfg = get_smoke_config(arch)
        mesh = make_test_mesh(2, 4)
        params = abstract_params(cfg, mesh)
        cache = abstract_cache(cfg, 8, 64, mesh)
        cache = dict(cache)
        cache["pos"] = jax.ShapeDtypeStruct((), jnp.int32,
                                            sharding=NamedSharding(mesh, P()))
        token = jax.ShapeDtypeStruct((8, 1), jnp.int32,
                                     sharding=NamedSharding(mesh, P("data", None)))
        fn = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
        compiled = fn.lower(params, token, cache).compile()
        assert compiled.cost_analysis() is not None
        print("DRYRUN-OK", arch)
    """)
    assert out.count("DRYRUN-OK") == 3


# ----------------------------------------------- shard_map compat wrapper --
# The wrapper accepts the jax >= 0.5 spelling (axis_names=/check_vma=) and
# translates to whichever implementation the installed jax provides. Both
# dispatch paths run in-process (a 1x1 mesh needs no device forcing).


def _wrapper_inputs():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(8, dtype=jnp.float32)
    return mesh, x, P("data"), P("data")


def test_shard_map_wrapper_new_spelling(monkeypatch):
    """With jax.shard_map present (0.5.x), the wrapper forwards check_vma
    and normalizes axis_names to a set."""
    import jax
    from repro.distributed.sharding import shard_map

    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma, **kw):
        seen.update(kw, check_vma=check_vma)
        return f

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    mesh, x, in_s, out_s = _wrapper_inputs()
    fn = shard_map(lambda v: v * 2, mesh=mesh, in_specs=(in_s,),
                   out_specs=out_s, axis_names=("data",), check_vma=False)
    assert seen == {"check_vma": False, "axis_names": {"data"}}
    assert float(fn(x)[3]) == 6.0          # wrapper returned the mapped fn


def test_shard_map_wrapper_legacy_spelling(monkeypatch):
    """Without jax.shard_map (0.4.x), the wrapper must reach
    jax.experimental.shard_map with replication checking off (fully manual
    mode) — and the mapped function must actually compute."""
    import jax
    import jax.experimental.shard_map as esm
    import numpy as np
    from repro.distributed.sharding import shard_map

    monkeypatch.delattr(jax, "shard_map", raising=False)
    real, seen = esm.shard_map, {}

    def spy(f, *, mesh, in_specs, out_specs, **kw):
        seen.update(kw)
        return real(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    monkeypatch.setattr(esm, "shard_map", spy)
    mesh, x, in_s, out_s = _wrapper_inputs()
    fn = shard_map(lambda v: v + 1, mesh=mesh, in_specs=(in_s,),
                   out_specs=out_s, check_vma=True)
    assert seen == {"check_rep": False}
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)),
                                  np.asarray(x) + 1)


# ------------------------------------------------------------- straggler ---


def test_straggler_reissue_on_slow_worker():
    """One synthetic slow worker: its units blow the p95 deadline, get
    reissued to healthy workers, and every unit still completes exactly once
    with the right value (first completion wins, duplicates suppressed)."""
    from repro.distributed.straggler import run_with_stragglers

    slow = lambda wid: 0.4 if wid == 0 else 0.002
    results, stats = run_with_stragglers(
        list(range(10)), lambda p: p * p, n_workers=3,
        deadline_factor=2.0, min_deadline_s=0.05, worker_delay=slow)
    assert results == {i: i * i for i in range(10)}
    assert stats.completed == 10
    assert stats.reissued >= 1            # the slow worker's unit was duped
    # a duplicated unit that both copies finish is suppressed, not double-
    # counted: completions never exceed the unit count
    assert stats.completed + stats.duplicates_suppressed >= 10


def test_straggler_no_reissue_when_healthy():
    from repro.distributed.straggler import run_with_stragglers

    results, stats = run_with_stragglers(
        list(range(6)), lambda p: p + 1, n_workers=3,
        deadline_factor=50.0, min_deadline_s=5.0)
    assert results == {i: i + 1 for i in range(6)}
    assert stats.reissued == 0
