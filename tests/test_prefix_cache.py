"""Shared-prefix KV reuse (DESIGN.md §10): semantics tests.

The prefix cache is a serving-layer saving only — with it on or off the
engine must decode byte-identical outputs, the extractor must return
identical result rows, and the ledger token columns must not move; the
saving shows up solely in `prefill_tokens` (strictly lower) and in the
separately-reported `saved_prefill_tokens`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.ledger import CostLedger
from repro.core.scheduler import BatchScheduler
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.extract.served import ServedExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.models import init_decode_cache, init_params
from repro.models.cache_ops import (cache_nbytes, expand_snapshot,
                                    prefix_snapshot, slot_cache, write_slot)
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import PrefixCache


# ------------------------------------------------------------ store unit ---


def test_prefix_store_longest_proper_prefix():
    pc = PrefixCache(max_entries=8)
    pc.insert([1, 2], {"pos": jnp.int32(2)})
    pc.insert([1, 2, 3, 4], {"pos": jnp.int32(4)})
    hit = pc.match([1, 2, 3, 4, 9, 9])
    assert hit is not None and hit.tokens == (1, 2, 3, 4)
    # an entry equal to the whole prompt is NOT a hit (proper prefix only:
    # at least one suffix token must be prefilled to produce logits)
    hit = pc.match([1, 2, 3, 4])
    assert hit is not None and hit.tokens == (1, 2)
    assert pc.match([5, 6, 7]) is None
    assert pc.stats.hits == 2 and pc.stats.misses == 1


def test_prefix_store_lru_eviction():
    pc = PrefixCache(max_entries=2)
    pc.insert([1], {"pos": jnp.int32(1)})
    pc.insert([2], {"pos": jnp.int32(1)})
    assert pc.match([1, 9]) is not None          # touch [1] -> [2] is LRU
    pc.insert([3], {"pos": jnp.int32(1)})
    assert len(pc) == 2 and pc.stats.evictions == 1
    assert pc.match([2, 9]) is None              # [2] was evicted
    assert pc.match([1, 9]) is not None


def test_prefix_store_byte_budget():
    big = {"k": jnp.zeros((2, 1, 16, 4), jnp.float32)}
    pc = PrefixCache(max_entries=64, max_bytes=int(1.5 * cache_nbytes(big)))
    pc.insert([1], dict(big))
    pc.insert([2], dict(big))
    assert len(pc) == 1 and pc.nbytes <= pc.max_bytes


# ------------------------------------------------------- cache_ops unit ----


def test_cache_ops_slot_and_snapshot_roundtrip():
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    cache = init_decode_cache(cfg, 3, 16)
    cache["pos"] = jnp.zeros((3,), jnp.int32)
    key = jax.random.PRNGKey(0)
    filled = {k: (jax.random.normal(key, v.shape, v.dtype)
                  if jnp.issubdtype(v.dtype, jnp.floating) else v)
              for k, v in cache.items()}
    filled["pos"] = jnp.asarray([3, 7, 5], jnp.int32)
    sub = slot_cache(filled, 1)
    assert int(sub["pos"]) == 7
    back = write_slot(filled, sub, 1)
    for k in filled:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(filled[k]))
    # snapshot trims the token axis to the prefix; expand zero-pads it back
    snap = prefix_snapshot(sub, 5)
    assert snap["k"].shape[2] == 5 and int(snap["pos"]) == 5
    assert cache_nbytes(snap) < cache_nbytes(sub)
    full = expand_snapshot(snap, 16)
    assert full["k"].shape == sub["k"].shape
    np.testing.assert_array_equal(np.asarray(full["k"][:, :, :5]),
                                  np.asarray(sub["k"][:, :, :5]))
    assert not np.asarray(full["k"][:, :, 5:]).any()


# ------------------------------------------------------ engine semantics ---


def _engine_outputs(cfg, params, prompts, shared_len, *, prefix_cache,
                    max_new=5):
    eng = ServingEngine(cfg, params, slots=2, max_len=64,
                        prefix_cache=prefix_cache, prefix_min_len=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=max_new, eos_id=-1,
                           shared_len=shared_len))
    done = eng.run()
    return eng, {i: done[i].out for i in range(len(prompts))}


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "falcon-mamba-7b"])
def test_engine_prefix_cache_identical_outputs(arch):
    """Decoded outputs are byte-identical with the cache on or off, for an
    attention family and an SSM family (recurrent state at the prefix
    boundary must be exact, not just position-indexed KV)."""
    cfg = get_smoke_config(arch).replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    shared = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7, 3, 2]
    prompts = [shared + [10 + i, 20 + i, 30 + i] for i in range(4)]
    eng_off, off = _engine_outputs(cfg, params, prompts, len(shared),
                                   prefix_cache=False)
    eng_on, on = _engine_outputs(cfg, params, prompts, len(shared),
                                 prefix_cache=True)
    assert on == off
    # strictly fewer prefill tokens, savings reported separately
    assert eng_on.stats["prefill_tokens"] < eng_off.stats["prefill_tokens"]
    assert eng_on.stats["prefix_hits"] == 3
    assert eng_on.stats["prefix_saved_tokens"] == 3 * len(shared)
    assert eng_off.stats["prefix_hits"] == 0
    # accounting identity: prefilled + saved == the cache-off prefill total
    assert (eng_on.stats["prefill_tokens"] +
            eng_on.stats["prefix_saved_tokens"]) == \
        eng_off.stats["prefill_tokens"]


def test_engine_accepts_configured_prefix_cache_instance():
    """A user-supplied (initially empty, hence falsy) PrefixCache must be
    used, not silently discarded."""
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pc = PrefixCache(max_entries=4)
    shared = [7, 3, 9, 4, 2, 8, 1, 6]
    prompts = [shared + [10 + i, 20 + i] for i in range(3)]
    eng, _ = _engine_outputs(cfg, params, prompts, len(shared),
                             prefix_cache=pc)
    assert eng.prefix_cache is pc
    assert pc.stats.hits == 2 and len(pc) == 1


def test_engine_prefix_cache_no_boundary_is_noop():
    """Requests without a shared_len annotation never snapshot or hit."""
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9, i] for i in range(3)]
    eng, _ = _engine_outputs(cfg, params, prompts, 0, prefix_cache=True)
    assert eng.stats["prefix_hits"] == 0
    assert eng.stats["prefix_inserts"] == 0
    assert len(eng.prefix_cache) == 0


# ------------------------------------------------- extractor + scheduler ---


def _served_run(corpus, retr, items, *, prefix_cache):
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=4, max_len=1024,
                        prefix_cache=prefix_cache)
    extractor = ServedExtractor(corpus, eng, max_new=6)
    ledger = CostLedger()
    sched = BatchScheduler(retr, extractor, ledger, {}, batch_size=8)
    out = sched.extract_many(items)
    return eng, extractor, ledger, out


def test_served_prefix_cache_rows_and_ledger_invariant():
    """End-to-end through scheduler + served extractor: identical result
    rows and ledger token columns; prefill strictly lower; savings threaded
    into ServedStats and CostLedger."""
    corpus = make_swde_corpus()
    retr = TwoLevelRetriever(corpus, mode="rag_topk")
    docs = sorted(corpus.tables["universities"])[:5]
    items = [(d, a, "universities") for d in docs
             for a in ("tuition", "enrollment")]

    eng_off, ex_off, led_off, out_off = _served_run(
        corpus, retr, items, prefix_cache=False)
    eng_on, ex_on, led_on, out_on = _served_run(
        corpus, retr, items, prefix_cache=True)

    assert out_on == out_off                       # byte-identical rows
    assert led_on.input_tokens == led_off.input_tokens
    assert led_on.output_tokens == led_off.output_tokens
    assert led_on.per_phase == led_off.per_phase
    assert eng_on.stats["prefill_tokens"] < eng_off.stats["prefill_tokens"]
    assert ex_on.stats.prefix_hits > 0
    assert ex_on.stats.saved_prefill_tokens > 0
    assert led_on.prefix_hits == ex_on.stats.prefix_hits
    assert led_on.saved_prefill_tokens == ex_on.stats.saved_prefill_tokens
    assert led_off.saved_prefill_tokens == 0


def test_scheduler_groups_by_shared_prefix():
    """Interleaved (attr, table) needs are stable-grouped so same-prefix
    requests land in the same chunk."""
    keys = [("d1", "a", "t"), ("d1", "b", "t"), ("d2", "a", "t"),
            ("d2", "b", "t"), ("d3", "a", "t")]
    grouped = BatchScheduler._group_by_prefix(keys)
    assert grouped == [("d1", "a", "t"), ("d2", "a", "t"), ("d3", "a", "t"),
                       ("d1", "b", "t"), ("d2", "b", "t")]
