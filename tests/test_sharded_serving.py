"""Mesh-aware serving parity (DESIGN.md §15).

The bar is *byte-identical rows*: a `ServingEngine` given a `mesh=` (CPU
meshes via the XLA host-device override, so these run in subprocesses like
tests/test_distributed.py) must decode exactly the tokens the single-device
engine decodes — across model families, KV layouts, prefix-cache settings
and speculative decoding, on both a pure-TP (1x2) and a mixed (2x2) mesh.
Sharding is a layout change, never a numerics change.

`ReplicaGroup` (data-parallel engines behind one shared queue) is held to
the same bar in-process, plus the stats contract: per-token counters summed
over replicas equal the single-engine totals on the same workload, and the
aggregate lands in one long-lived dict (`group.stats` stays the same object
across runs — `ServedExtractor` keeps a reference and reads deltas), not a
last-writer-wins merge of replica dicts.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_child(code: str, devices: int = 4, timeout: int = 540,
              prelude: bool = False):
    # dedent BEFORE prepending the (zero-indented) prelude: otherwise the
    # indented snippet would parse as dead code inside the prelude's last def
    prog = (PRELUDE if prelude else "") + textwrap.dedent(code)
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, \
        f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    if "MESH-SKIP" in res.stdout:
        pytest.skip("XLA host-device override ineffective in this environment")
    return res.stdout


# Shared child prelude: skip marker when forcing devices failed, plus the
# engine-run helper every parity child uses. The workload mirrors
# tests/test_paged_kv.py: a 12-token shared prefix + per-request tails.
PRELUDE = """
import jax
if len(jax.devices()) < 4:
    print("MESH-SKIP"); raise SystemExit(0)
from repro.configs import get_smoke_config
from repro.data import lm_data
from repro.models import init_params
from repro.launch.mesh import make_serving_mesh
from repro.serving.engine import Request, ServingEngine

SHARED = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7, 3, 2]
PROMPTS = [SHARED + [10 + i, 20 + i, 30 + i] for i in range(4)]

def build(arch):
    cfg = get_smoke_config(arch).replace(vocab_size=lm_data.VOCAB)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))

def rows(cfg, params, *, layout, pc, spec, mesh=None):
    eng = ServingEngine(cfg, params, slots=2, max_len=64, kv_layout=layout,
                        prefix_cache=pc, prefix_min_len=4, page_size=8,
                        chunk_size=5, spec_decode=spec, mesh=mesh)
    eng.submit_many([Request(rid=i, prompt=p, max_new=4, eos_id=-1,
                             shared_len=len(SHARED))
                     for i, p in enumerate(PROMPTS)])
    done = eng.run()
    return {i: list(done[i].out) for i in range(len(PROMPTS))}
"""


# One representative combo per family, cycling layouts / prefix cache /
# speculation so every feature meets every family class somewhere; the full
# combo matrix runs on the cheapest family below.
FAMILY_COMBOS = [
    ("qwen2.5-3b", "paged", True, "prompt_lookup"),     # dense
    ("deepseek-v2-lite-16b", "paged", False, "off"),    # moe + MLA
    ("falcon-mamba-7b", "slab", True, "off"),           # ssm
    ("zamba2-2.7b", "paged", True, "off"),              # hybrid
    ("whisper-medium", "slab", False, "off"),           # encdec
    ("llava-next-mistral-7b", "paged", False, "prompt_lookup"),  # vlm
]


@pytest.mark.parametrize("arch,layout,pc,spec", FAMILY_COMBOS,
                         ids=[c[0] for c in FAMILY_COMBOS])
def test_mesh_rows_identical_all_families(arch, layout, pc, spec):
    """Single-device vs 1x2 (pure TP) vs 2x2 (DP x TP): byte-identical."""
    out = run_child(f"""
    cfg, params = build({arch!r})
    kw = dict(layout={layout!r}, pc={pc}, spec={spec!r})
    ref = rows(cfg, params, **kw)
    for shape in ((1, 2), (2, 2)):
        got = rows(cfg, params, mesh=make_serving_mesh(shape), **kw)
        assert got == ref, (shape, ref, got)
    print("PARITY-OK", ref)
    """, prelude=True)
    assert "PARITY-OK" in out


def test_mesh_rows_identical_full_matrix():
    """The full {paged,slab} x {pc off,on} x {spec off,prompt_lookup} matrix
    on the dense family, one child process, 2x2 mesh."""
    out = run_child("""
    cfg, params = build("qwen2.5-3b")
    mesh = make_serving_mesh((2, 2))
    n = 0
    for layout in ("paged", "slab"):
        for pc in (False, True):
            for spec in ("off", "prompt_lookup"):
                kw = dict(layout=layout, pc=pc, spec=spec)
                ref = rows(cfg, params, **kw)
                got = rows(cfg, params, mesh=mesh, **kw)
                assert got == ref, (layout, pc, spec, ref, got)
                n += 1
    print("MATRIX-OK", n)
    """, prelude=True, timeout=900)
    assert "MATRIX-OK 8" in out


def test_replica_group_on_mesh_rows_identical():
    """DP replicas stacked on a TP mesh: 2 replicas, each engine on a 1x2
    mesh, rows byte-identical to one single-device engine."""
    out = run_child("""
    from repro.serving.replicas import ReplicaGroup
    cfg, params = build("qwen2.5-3b")
    kw = dict(slots=2, max_len=64, kv_layout="paged", prefix_cache=True,
              prefix_min_len=4, page_size=8, chunk_size=5,
              spec_decode="prompt_lookup")
    reqs = lambda: [Request(rid=i, prompt=p, max_new=4, eos_id=-1,
                            shared_len=len(SHARED))
                    for i, p in enumerate(PROMPTS)]
    eng = ServingEngine(cfg, params, **kw)
    eng.submit_many(reqs())
    ref = {i: list(r.out) for i, r in eng.run().items()}
    grp = ReplicaGroup(cfg, params, replicas=2,
                       mesh=make_serving_mesh((1, 2)), **kw)
    grp.submit_many(reqs())
    got = {i: list(r.out) for i, r in grp.run().items()}
    assert got == ref, (ref, got)
    print("GROUP-MESH-OK")
    """, prelude=True)
    assert "GROUP-MESH-OK" in out


def test_make_serving_mesh_validates():
    from repro.launch.mesh import parse_mesh_shape

    assert parse_mesh_shape("2x2") == (2, 2)
    assert parse_mesh_shape("1,4") == (1, 4)
    assert parse_mesh_shape((4, 1)) == (4, 1)
    for bad in ("3", "2x2x2", "0x4"):
        with pytest.raises(ValueError):
            parse_mesh_shape(bad)
    # device-count validation carries the XLA_FLAGS recipe (subprocess: the
    # parent test process may itself be running with forced devices)
    out = run_child("""
    import jax
    from repro.launch.mesh import make_serving_mesh
    try:
        make_serving_mesh((4, 4))
    except RuntimeError as e:
        assert "xla_force_host_platform_device_count=16" in str(e), e
        print("MESH-VALIDATE-OK")
    """, devices=1)
    assert "MESH-VALIDATE-OK" in out


# ---------------------------------------------------- in-process replicas --
# Single-device: ReplicaGroup parity and the stats-aggregation contract do
# not need a mesh, so these run in the main pytest process.

import jax  # noqa: E402  (after the subprocess-only section on purpose)

from repro.configs import get_smoke_config  # noqa: E402
from repro.data import lm_data  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving.engine import Request, ServingEngine  # noqa: E402
from repro.serving.replicas import (PEAK_KEYS, ReplicaGroup,  # noqa: E402
                                    aggregate_stats)

SHARED = [7, 3, 9, 4, 2, 8, 1, 6, 5, 7, 3, 2]

# counters where replica-sum must equal the single-engine total on an
# identical workload (batch-shape-dependent counters like decode_steps or
# max_live legitimately differ across replica splits)
SUM_EQUAL_KEYS = ["prefill_tokens", "prefix_hits", "prefix_saved_tokens",
                  "prefix_inserts", "decode_slot_steps", "draft_tokens",
                  "accepted_tokens", "decode_steps_saved"]


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _reqs(n=8, max_new=6):
    return [Request(rid=i, prompt=SHARED + [10 + i, 20 + i, 30 + i],
                    max_new=max_new, eos_id=-1, shared_len=len(SHARED))
            for i in range(n)]


ENGINE_KW = dict(slots=2, max_len=64, prefix_cache=True, prefix_min_len=4,
                 page_size=8, chunk_size=5)


@pytest.mark.parametrize("layout,spec", [("paged", "prompt_lookup"),
                                         ("paged", "off"), ("slab", "off")])
def test_replica_group_rows_match_single_engine(qwen, layout, spec):
    cfg, params = qwen
    kw = dict(ENGINE_KW, kv_layout=layout, spec_decode=spec)
    eng = ServingEngine(cfg, params, **kw)
    eng.submit_many(_reqs())
    ref = {i: list(r.out) for i, r in eng.run().items()}
    grp = ReplicaGroup(cfg, params, replicas=2, **kw)
    grp.submit_many(_reqs())
    got = {i: list(r.out) for i, r in grp.run().items()}
    assert got == ref


def test_replica_stats_sum_equals_single_engine(qwen):
    """Regression for last-writer-wins aggregation: every per-token counter
    summed across replicas equals the single-engine total, and the group's
    own dict carries exactly that sum."""
    cfg, params = qwen
    kw = dict(ENGINE_KW, kv_layout="paged", spec_decode="prompt_lookup")
    eng = ServingEngine(cfg, params, **kw)
    eng.submit_many(_reqs())
    eng.run()
    grp = ReplicaGroup(cfg, params, replicas=2, **kw)
    grp.submit_many(_reqs())
    grp.run()
    for k in SUM_EQUAL_KEYS:
        assert grp.stats[k] == eng.stats[k], (
            f"{k}: replica-sum {grp.stats[k]} != single {eng.stats[k]}")
        assert grp.stats[k] == sum(e.stats[k] for e in grp.engines), k
    # at least one counter must be attributable to BOTH replicas, or the
    # "sum" above degenerates into one engine doing all the work
    assert all(e.stats["decode_slot_steps"] > 0 for e in grp.engines)


def test_replica_stats_live_dict_and_run_accounting(qwen):
    """`group.stats` is one long-lived dict updated in place (the extractor
    holds a reference across runs), and runs/truncations are group-level."""
    cfg, params = qwen
    grp = ReplicaGroup(cfg, params, replicas=2, kv_layout="paged", **ENGINE_KW)
    ref = grp.stats
    grp.submit_many(_reqs(4))
    grp.run()
    assert ref is grp.stats and ref["runs"] == 1
    before = ref["prefill_tokens"]
    grp.submit_many(_reqs(4))
    grp.run()
    assert ref is grp.stats and ref["runs"] == 2
    assert ref["prefill_tokens"] > before     # second run visible via old ref
    assert all(e.stats["runs"] == 0 for e in grp.engines)


def test_aggregate_stats_sums_and_peaks():
    a = {"prefill_tokens": 3, "max_live": 2, "kv_bytes_peak": 100}
    b = {"prefill_tokens": 5, "max_live": 4, "kv_bytes_peak": 70, "extra": 1}
    agg = aggregate_stats([a, b])
    assert agg == {"prefill_tokens": 8, "max_live": 4, "kv_bytes_peak": 100,
                   "extra": 1}
    assert set(PEAK_KEYS) == {"max_live", "kv_bytes_peak"}
    into = {"stale": 9}
    out = aggregate_stats([a, b], into=into)
    assert out is into and "stale" not in into and into["max_live"] == 4


def test_replica_group_queue_depth_and_failed(qwen):
    cfg, params = qwen
    grp = ReplicaGroup(cfg, params, replicas=2, queue_depth=3,
                       kv_layout="paged", **ENGINE_KW)
    grp.submit_many(_reqs(3))
    with pytest.raises(RuntimeError, match="queue full"):
        grp.submit(_reqs(4)[3])
    # all-or-nothing: an over-depth batch leaves the queue untouched
    with pytest.raises(RuntimeError, match="queue full"):
        grp.submit_many(_reqs(2))
    assert len(grp.queue) == 3
    grp.run()
    assert set(grp.finished) == {0, 1, 2} and grp.failed == {}


def test_replica_group_shared_prefix_cache_and_pool(qwen):
    """Cross-replica prefix sharing: exactly one insert serves hits on every
    replica, and with the shared paged pool all entry pages live in ONE
    allocator (refcounted across replicas)."""
    cfg, params = qwen
    grp = ReplicaGroup(cfg, params, replicas=2, kv_layout="paged", **ENGINE_KW)
    assert all(e.alloc is grp.engines[0].alloc for e in grp.engines)
    assert all(e.prefix_cache is grp.prefix_cache for e in grp.engines)
    grp.submit_many(_reqs())
    grp.run()
    assert grp.stats["prefix_inserts"] == 1
    assert grp.stats["prefix_hits"] == 7
    # every slot's pages released; only the cached prefix entry pins pages
    alloc = grp.engines[0].alloc
    entry = next(iter(grp.prefix_cache._entries.values()))
    live = len(entry.pages) + (1 if entry.tail_page is not None else 0)
    assert alloc.used_pages == live
