"""Runtime tests: serving engine, checkpoint/restart, straggler mitigation,
data pipeline determinism, optimizers.
"""
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_data
from repro.data.corpus import make_swde_corpus
from repro.distributed.straggler import run_with_stragglers
from repro.models import decode_step, forward, init_params, prefill
from repro.serving.engine import Request, RunTruncated, ServingEngine
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.driver import CrashInjected, Trainer, TrainerConfig
from repro.training.optim import OptConfig
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------- serving -----


def _reference_generate(cfg, params, prompt, n_new):
    """Greedy generation via repeated full forward (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(cfg, params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_reference(tiny):
    cfg, params = tiny
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5], [3, 1], [2, 6, 4]]
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6, eos_id=-1))
    done = eng.run()
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        want = _reference_generate(cfg, params, p, 6)
        assert done[i].out == want, (i, done[i].out, want)
    # continuous batching actually reused slots (5 requests, 2 slots)
    assert eng.stats["decode_steps"] > 0


def test_engine_eviction_requeues(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=5, eos_id=-1))
    # insert, decode one step, then simulate worker failure
    eng._insert(0, eng.queue.popleft())
    eng._step()
    eng.drain_slot(0)
    assert eng.stats["evictions"] == 1
    done = eng.run()
    assert done[0].retries == 1
    assert done[0].out == _reference_generate(cfg, params, [1, 2, 3], 5)


def test_engine_run_truncation_is_loud(tiny):
    """Exhausting max_steps with work still pending must not read as a
    complete run: strict mode raises, non-strict flags it in stats."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new=8, eos_id=-1))
    with pytest.raises(RunTruncated) as exc:
        eng.run(max_steps=2)
    assert eng.stats["truncations"] == 1
    assert len(exc.value.finished) < 3
    # non-strict callers get partial results plus the flag
    eng2 = ServingEngine(cfg, params, slots=1, max_len=32)
    for i in range(3):
        eng2.submit(Request(rid=i, prompt=[1, 2, 3], max_new=8, eos_id=-1))
    done = eng2.run(max_steps=2, strict=False)
    assert eng2.stats["truncations"] == 1 and len(done) < 3
    # the same engine can finish the drain afterwards
    assert len(eng2.run()) == 3


def test_engine_drain_slot_retry_cap(tiny):
    """A persistently failing slot must not requeue forever: past
    max_retries the request fails visibly instead."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=5, eos_id=-1,
                       max_retries=2))
    for _ in range(10):                      # persistent slot failure
        if eng.queue:
            eng._insert(0, eng.queue.popleft())
        if not eng.active:
            break
        eng.drain_slot(0)
    assert 0 in eng.failed and eng.failed[0].error is not None
    assert eng.failed[0].retries == 3        # initial + 2 retries, then fail
    assert eng.stats["failures"] == 1
    assert not eng.queue and not eng.active  # run() would terminate
    assert eng.run() == {}


# ---------------------------------------------------------- checkpoints ----


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    save_checkpoint(tmp_path, 7, {"params": params}, extra={"step": 7})
    assert latest_step(tmp_path) == 7
    tree, extra = restore_checkpoint(tmp_path, 7, {"params": params})
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _make_trainer(cfg, tmp, total=12, ckpt_every=4, seed=0):
    corpus = make_swde_corpus()
    stream = lm_data.corpus_token_stream(corpus)
    data = lm_data.LMBatches(stream, batch=2, seq=16)
    tcfg = TrainerConfig(total_steps=total, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp), seed=seed, log_every=100)
    return Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=2), data, tcfg)


def test_crash_resume_bit_exact(tmp_path, tiny):
    cfg, _ = tiny
    # run A: straight through
    t_a = _make_trainer(cfg, tmp_path / "a")
    t_a.init()
    hist_a = t_a.run()
    # run B: crash at step 6, restart from checkpoint (step 4), continue
    t_b = _make_trainer(cfg, tmp_path / "b")
    t_b.init()
    with pytest.raises(CrashInjected):
        t_b.run(failure_at=6)
    t_b.ckpt.wait()
    t_b2 = _make_trainer(cfg, tmp_path / "b")
    t_b2.init()          # build like-tree for restore
    assert t_b2.resume()
    assert t_b2.step == 4
    t_b2.run()
    # losses from the resumed run must match the uninterrupted run exactly
    np.testing.assert_allclose(hist_a[4:], t_b2.history, rtol=0, atol=0)


# ------------------------------------------------------------ straggler ----


def test_straggler_reissue_completes_faster():
    def work(x):
        time.sleep(0.01)
        return x * x

    slow = lambda wid: 0.4 if wid == 0 else 0.0   # worker 0 is a straggler
    results, stats = run_with_stragglers(range(12), work, n_workers=3,
                                         worker_delay=slow,
                                         deadline_factor=3.0)
    assert results == {i: i * i for i in range(12)}
    assert stats.reissued >= 1          # the straggler's units were duplicated
    assert stats.completed == 12


# ------------------------------------------------------------- lm data -----


def test_lm_data_deterministic_resume():
    corpus = make_swde_corpus()
    stream = lm_data.corpus_token_stream(corpus)
    a = lm_data.LMBatches(stream, batch=2, seq=8)
    batches = [a.next() for _ in range(5)]
    snap = a.snapshot()
    more_a = [a.next() for _ in range(3)]
    b = lm_data.LMBatches(stream, batch=2, seq=8)
    b.restore(snap)
    more_b = [b.next() for _ in range(3)]
    for x, y in zip(more_a, more_b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


# ------------------------------------------------------------ optimizers ---


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "adam8bit"])
def test_optimizers_reduce_loss(opt, tiny):
    cfg, _ = tiny
    params = init_params(cfg, jax.random.PRNGKey(1))
    init_fn, step = make_train_step(cfg, OptConfig(name=opt, lr=2e-3, warmup_steps=1))
    state = init_fn(params)
    step = jax.jit(step)
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (4, 17), 0, 64)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(12):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (opt, losses[0], losses[-1])
    assert np.isfinite(losses).all()
