"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward pass (shape + finiteness), one gradient
step, and prefill/decode consistency against the full forward — the strongest
cheap correctness check a serving stack has.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, forward, init_params, prefill)
from repro.models.model import VISION_DIM

B, T = 2, 16


def make_batch(cfg, key, seq=T):
    ks = jax.random.split(key, 3)
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    batch = {"tokens": jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[1], (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(ks[2], (B, n_img, VISION_DIM), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_grad_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng)

    def loss_fn(p):
        logits, aux = forward(cfg, p, batch)
        labels = jnp.roll(batch["tokens"], -1, axis=1)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    logits_full, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)

    # prefill on the first T-1 tokens, then decode token T-1:
    pre_batch = dict(batch, tokens=batch["tokens"][:, : T - 1])
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    max_len = T + n_img + 4
    logits_pre, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_len))(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(logits_full[:, T - 2]),
        rtol=2e-4, atol=2e-4)

    logits_dec, cache = jax.jit(
        lambda p, t, c: decode_step(cfg, p, t, c))(params, batch["tokens"][:, T - 1:], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, T - 1]),
        rtol=2e-3, atol=2e-3)
