"""Tests for the GPipe pod-axis pipeline and the serving cost model."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.serving.costs import cost_table, serving_costs

from tests.test_distributed import run_child


def test_pipeline_matches_sequential():
    out = run_child("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.distributed.pipeline import pipeline_forward, bubble_fraction

    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    n_stages, n_micro, mb, d = 4, 6, 3, 8
    key = jax.random.PRNGKey(0)
    # stage = one linear+gelu block; params stacked over stages
    W = jax.random.normal(key, (n_stages, d, d)) * 0.3

    def stage_fn(w, x):
        return jax.nn.gelu(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    fwd = pipeline_forward(mesh, stage_fn, n_micro)
    got = jax.jit(fwd)(W, x)

    # sequential reference
    want = x
    for s in range(n_stages):
        want = jax.nn.gelu(want @ W[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
    print("PIPELINE-OK")
    """, devices=8)
    assert "PIPELINE-OK" in out


def test_serving_costs_all_archs():
    rows = cost_table(context=32768)
    assert len(rows) == 10
    by_arch = {r.arch: r for r in rows}
    # SSM: zero KV growth, nonzero recurrent state
    fm = by_arch["falcon-mamba-7b"]
    assert fm.kv_bytes_per_token == 0.0 and fm.state_bytes > 0
    # MLA compresses the cache far below GQA at similar scale
    dsv2 = by_arch["deepseek-v2-lite-16b"]
    qwen3 = by_arch["qwen3-32b"]
    assert dsv2.kv_bytes_per_token < qwen3.kv_bytes_per_token / 4
    # hybrid: attention cache only every attn_every layers
    z = by_arch["zamba2-2.7b"]
    full = get_config("zamba2-2.7b")
    assert z.kv_bytes_per_token == pytest.approx(
        (full.num_layers // full.attn_every) * 2 * full.n_kv_heads
        * full.resolved_head_dim * 2)
    # extraction_seconds monotone in tokens
    c = by_arch["qwen2.5-3b"]
    assert c.extraction_seconds(1000, 10) < c.extraction_seconds(2000, 10)
