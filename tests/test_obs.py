"""Unified telemetry (DESIGN.md §19): tracing, metrics registry, EXPLAIN
ANALYZE.

Pins the PR's contract:
  * metrics: typed instrument semantics (monotone counters, peak gauges,
    cumulative histograms), schema enforcement (undeclared name = hard
    error, `check_complete` catches silently-unreported metrics),
    `StatsDict` compat surface, Prometheus exposition;
  * tracing: stack nesting produces a well-formed span tree (hypothesis
    property when available, fixed program otherwise), async begin/end,
    levels gate emission, Chrome/JSONL exports are valid and — under the
    tick clock — byte-identical across identical runs on BOTH the oracle
    and the real served extractor;
  * parity: rows and ledger token columns are byte-identical with tracing
    off vs. full (observability must observe, never perturb);
  * `LatencySeries`: empty-window percentile guard and exact FIFO
    eviction at window / window+1;
  * EXPLAIN ANALYZE: `report()` joins per-stage estimated vs. actual
    selectivity and per-attr token actuals, and refuses unfinished
    queries.
"""
import json

import pytest

from repro.core import Engine, Filter, Query, Session, conj
from repro.data.corpus import make_swde_corpus, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever
from repro.obs import (LEVEL_FULL, LEVEL_OFF, LEVEL_PHASES, NULL_TRACER,
                       SCHEMA, MetricsRegistry, MetricsSchemaError, StatsDict,
                       TickClock, Tracer, as_tracer, resolve_level,
                       schema_stem)
from repro.obs.metrics import ENGINE_STATS
from repro.serving.costs import LatencySeries

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # container may not ship hypothesis
    given = settings = st = None


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_corpus(seed=0)


def _players_query():
    return Query(tables=["players"], select=[("players", "player_name")],
                 where=conj(Filter("age", ">", 30, table="players"),
                            Filter("all_stars", ">=", 5, table="players")))


# ------------------------------------------------------------ instruments --


def test_counter_is_monotone():
    reg = MetricsRegistry(schema=None)
    c = reg.counter("x.n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.set_total(9)
    with pytest.raises(MetricsSchemaError, match="decrease"):
        c.set_total(3)
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_and_peak():
    reg = MetricsRegistry(schema=None)
    g = reg.gauge("x.depth")
    g.set(7)
    g.set_max(3)            # lower: peak keeps 7
    assert g.value == 7
    g.set(2)                # plain set may go down (it is a gauge)
    assert g.value == 2


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry(schema=None)
    h = reg.histogram("x.lat", bounds=(1, 10, 100))
    for v in (0, 1, 5, 50, 5000):
        h.observe(v)
    val = h.value
    assert val["count"] == 5 and val["sum"] == 5056
    # cumulative le-counts: le=1 gets {0,1}, le=10 adds 5, le=100 adds 50
    assert val["buckets"] == {"1": 2, "10": 3, "100": 4, "+Inf": 5}


def test_registry_schema_enforced():
    reg = MetricsRegistry()          # repo-wide SCHEMA
    with pytest.raises(MetricsSchemaError, match="not in the registered"):
        reg.counter("engine.made_up_counter")
    with pytest.raises(MetricsSchemaError, match="declared as"):
        reg.counter("engine.max_live")       # schema says gauge
    c1 = reg.counter("engine.prefill_tokens")
    assert reg.counter("engine.prefill_tokens") is c1   # idempotent
    assert reg.get("engine.prefill_tokens") is c1
    with pytest.raises(MetricsSchemaError, match="never registered"):
        reg.get("engine.decode_steps")


def test_check_complete_catches_unreported_metric():
    reg = MetricsRegistry()
    for key in ENGINE_STATS:
        if key != "decode_steps":
            typ = ENGINE_STATS[key][0]
            getattr(reg, typ)(f"engine.{key}")
    with pytest.raises(MetricsSchemaError, match="decode_steps"):
        reg.check_complete("engine.")
    reg.counter("engine.decode_steps")
    reg.check_complete("engine.")        # now complete


def test_stats_dict_is_registry_backed():
    reg = MetricsRegistry()
    stats = StatsDict(reg, "engine", ENGINE_STATS)
    stats["prefill_tokens"] += 12
    stats["max_live"] = 3
    assert stats["prefill_tokens"] == 12
    assert reg.value("engine.prefill_tokens") == 12
    with pytest.raises(MetricsSchemaError):
        stats["made_up"] += 1
    with pytest.raises(MetricsSchemaError):
        stats["made_up"]
    with pytest.raises(MetricsSchemaError, match="decrease"):
        stats["prefill_tokens"] = 5
    snap = stats.snapshot()
    assert snap["prefill_tokens"] == 12 and len(snap) == len(ENGINE_STATS)
    assert stats == snap                 # dict-compat equality
    assert "prefill_tokens" in stats and "made_up" not in stats


def test_schema_stem_maps_bench_spellings():
    assert schema_stem("prefill_tokens") == "prefill_tokens"
    assert schema_stem("prefill_tokens_on") == "prefill_tokens"
    assert schema_stem("draft_tokens_dp2") == "draft_tokens"
    assert schema_stem("engine.prefill_tokens") == "engine.prefill_tokens"
    assert schema_stem("zorblax") is None


def test_exposition_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("engine.prefill_tokens").inc(42)
    reg.gauge("frontend.queue_depth_peak").set(3)
    h = reg.histogram("frontend.queue_delay")
    h.observe(2)
    text = reg.exposition()
    assert "# TYPE engine_prefill_tokens counter" in text
    assert "engine_prefill_tokens 42" in text
    assert "frontend_queue_depth_peak 3" in text
    assert 'frontend_queue_delay_bucket{le="+Inf"} 1' in text
    assert "frontend_queue_delay_count 1" in text


# ----------------------------------------------------------------- tracer --


def test_resolve_level():
    assert resolve_level("off") == LEVEL_OFF
    assert resolve_level("phases") == LEVEL_PHASES
    assert resolve_level("full") == LEVEL_FULL
    assert resolve_level(2) == LEVEL_FULL
    with pytest.raises(ValueError):
        resolve_level("loud")
    with pytest.raises(ValueError):
        resolve_level(7)


def test_span_nesting_and_parents():
    tr = Tracer(clock="ticks")
    with tr.span("outer", kind="a"):
        with tr.span("inner", kind="b", n=1):
            tr.instant("tick", kind="c")
    outer, inner, inst = tr.spans
    assert outer.parent is None
    assert inner.parent == outer.sid
    assert inst.parent == inner.sid and inst.phase == "i"
    assert outer.t0 < inner.t0 <= inner.t1 < outer.t1
    assert inner.attrs == {"n": 1}


def test_async_begin_end_outlives_stack():
    tr = Tracer(clock="ticks")
    sid = tr.begin("query", kind="query", qid=1)
    with tr.span("step", kind="s"):
        pass
    tr.end(sid, rows=3)
    q = tr.find("query")[0]
    assert q.phase == "b" and q.parent is None
    assert q.attrs == {"qid": 1, "rows": 3}
    assert q.t1 > q.t0
    tr.end(sid)                      # double-end is a no-op
    assert tr.begin("off", level=99) == -1


def test_levels_gate_emission():
    tr = Tracer(clock="ticks", level=LEVEL_PHASES)
    with tr.span("coarse"):
        with tr.span("fine", level=2):
            tr.instant("finer", level=2)
    assert [s.name for s in tr.spans] == ["coarse"]
    assert not tr.enabled(2) and tr.enabled(1)
    off = Tracer(clock="ticks", level=0)
    with off.span("nope"):
        pass
    assert off.spans == []


def test_exception_leak_closes_stack():
    tr = Tracer(clock="ticks")
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("leaked"):
                raise RuntimeError("boom")
    assert all(s.t1 is not None for s in tr.spans)
    assert tr._stack == []           # outer's close popped the leaked span


def test_null_tracer_is_inert():
    assert as_tracer(None) is NULL_TRACER
    t = Tracer(clock="ticks")
    assert as_tracer(t) is t
    with NULL_TRACER.span("x"):
        NULL_TRACER.instant("y")
    assert NULL_TRACER.end(NULL_TRACER.begin("z")) is None
    assert NULL_TRACER.to_jsonl() == "" and not NULL_TRACER.enabled()


def test_chrome_export_shape():
    tr = Tracer(clock="ticks")
    sid = tr.begin("query", kind="query")
    with tr.span("round", kind="scheduler", needs=2):
        tr.instant("hit", kind="engine")
    tr.end(sid)
    doc = json.loads(json.dumps(tr.to_chrome()))
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert phases.count("b") == 1 and phases.count("e") == 1
    assert phases.count("X") == 1 and phases.count("i") == 1
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert x["name"] == "round" and x["dur"] > 0 and x["args"] == {"needs": 2}
    assert doc["otherData"]["clock"] == "ticks"


def test_jsonl_export_parses_and_orders():
    tr = Tracer(clock="ticks")
    with tr.span("a"):
        tr.instant("b")
    lines = tr.to_jsonl().splitlines()
    objs = [json.loads(ln) for ln in lines]
    assert [o["name"] for o in objs] == ["a", "b"]
    assert all(o["t1"] is not None for o in objs)


# --------------------------------------- span-tree well-formedness (prop) --


def _run_program(program):
    """Execute an op list against a fresh tick tracer; unmatched opens are
    closed at the end (exports must finalize them)."""
    tr = Tracer(clock="ticks")
    ctxs = []
    for op in program:
        if op == "open":
            ctx = tr.span(f"s{len(tr.spans)}", kind="k")
            ctx.__enter__()
            ctxs.append(ctx)
        elif op == "close" and ctxs:
            ctxs.pop().__exit__(None, None, None)
        elif op == "instant":
            tr.instant(f"i{len(tr.spans)}", kind="k")
    while ctxs:
        ctxs.pop().__exit__(None, None, None)
    return tr


def _assert_well_formed(tr):
    spans = {s.sid: s for s in tr.spans}
    for s in tr.spans:
        assert s.t1 is not None and s.t1 >= s.t0
        if s.parent is not None:
            p = spans[s.parent]
            assert p.phase == "X"
            # a child lives strictly inside its parent's interval
            assert p.t0 < s.t0 and s.t1 < p.t1
    # siblings never overlap (single-threaded pump)
    for s in tr.spans:
        sibs = [c for c in tr.spans
                if c.parent == s.parent and c.phase == "X"]
        sibs.sort(key=lambda c: c.t0)
        for a, b in zip(sibs, sibs[1:]):
            assert a.t1 < b.t0


if st is not None:
    @settings(max_examples=80, deadline=None)
    @given(st.lists(st.sampled_from(["open", "close", "instant"]),
                    max_size=40))
    def test_span_tree_well_formed_property(program):
        tr = _run_program(program)
        _assert_well_formed(tr)
        # determinism: same program -> byte-identical export
        assert tr.to_jsonl() == _run_program(program).to_jsonl()
else:
    def test_span_tree_well_formed_property():
        for program in (
            ["open", "open", "instant", "close", "open", "close", "close"],
            ["close", "instant", "open", "open", "open", "close"],
            ["open"] * 7 + ["instant"] + ["close"] * 3,
            ["instant", "instant"],
            [],
        ):
            tr = _run_program(program)
            _assert_well_formed(tr)
            assert tr.to_jsonl() == _run_program(program).to_jsonl()


# ---------------------------------------------------------- latency series --


def test_latency_series_empty_window_guard():
    s = LatencySeries(window=4)
    assert s.percentile(50) is None
    assert s.mean is None
    assert s.snapshot() == {"count": 0, "mean": None, "p50": None, "p99": None}


def test_latency_series_fifo_eviction_at_window_boundary():
    s = LatencySeries(window=4)
    for v in (40, 10, 30, 20):          # exactly `window` samples: all kept
        s.add(v)
    assert s.count == 4 and sorted(s._fifo) == s._sorted == [10, 20, 30, 40]
    assert s.percentile(0) == 10 and s.percentile(100) == 40
    s.add(25)                           # window+1: oldest (40) evicts, FIFO
    assert s.count == 5                 # lifetime count keeps the evicted
    assert s._sorted == [10, 20, 25, 30]
    assert s.percentile(100) == 30
    # out-of-range percentiles clamp instead of indexing out of bounds
    assert s.percentile(-5) == 10 and s.percentile(250) == 30


# -------------------------------------------- determinism + parity (oracle) --


def _traced_oracle_run(wiki, tracer):
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8, tracer=tracer)
    h = sess.submit(_players_query())
    return h.result(), h


def test_trace_determinism_oracle(wiki):
    t1 = Tracer(clock="ticks", level=LEVEL_FULL)
    t2 = Tracer(clock="ticks", level=LEVEL_FULL)
    _traced_oracle_run(wiki, t1)
    _traced_oracle_run(wiki, t2)
    assert t1.spans, "oracle run emitted no spans"
    assert t1.to_jsonl() == t2.to_jsonl()


def test_tracing_parity_oracle(wiki):
    res_off, _ = _traced_oracle_run(wiki, None)
    res_on, _ = _traced_oracle_run(wiki, Tracer(clock="ticks",
                                                level=LEVEL_FULL))
    key = lambda r: tuple(sorted(r["_docs"].items()))  # noqa: E731
    assert sorted(map(key, res_off.rows)) == sorted(map(key, res_on.rows))
    a, b = res_off.ledger, res_on.ledger
    for col in ("input_tokens", "output_tokens", "llm_calls", "extractions",
                "per_phase"):
        assert getattr(a, col) == getattr(b, col), col


def test_trace_covers_session_scheduler_layers(wiki):
    tr = Tracer(clock="ticks", level=LEVEL_FULL)
    _traced_oracle_run(wiki, tr)
    names = {s.name for s in tr.spans}
    assert {"session.query", "session.step", "scheduler.round"} <= names
    kinds = tr.by_kind()
    assert kinds["query"]["spans"] >= 1 and kinds["scheduler"]["spans"] >= 1


# --------------------------------------------- determinism + parity (served) --


def _served_session(corpus, cfg, params, tracer):
    from repro.extract.served import ServedExtractor
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, params, slots=4, max_len=1024,
                        prefix_cache=True, tracer=tracer)
    sess = Session(TwoLevelRetriever(corpus),
                   ServedExtractor(corpus, eng, max_new=6),
                   batch_size=4, tracer=as_tracer(tracer))
    return sess, eng


@pytest.fixture(scope="module")
def served_env():
    import jax
    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.models import init_params
    full = make_swde_corpus()
    uni = [d for d in sorted(full.docs) if "universities" in d][:6]
    corpus = full.subset(uni)
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return corpus, cfg, params


def _uni_query():
    return Query(tables=["universities"],
                 select=[("universities", "university_name")],
                 where=Filter("tuition", "<", 30000, table="universities"))


def test_trace_determinism_and_parity_served(served_env):
    """One tracer shared by session + engine: two identical runs produce
    byte-identical JSONL; rows/tokens match the untraced run; the trace
    covers session -> scheduler -> engine."""
    corpus, cfg, params = served_env
    results, traces = [], []
    for tracer in (Tracer(clock="ticks", level=LEVEL_FULL),
                   Tracer(clock="ticks", level=LEVEL_FULL), None):
        sess, eng = _served_session(corpus, cfg, params, tracer)
        results.append(sess.submit(_uni_query()).result())
        traces.append(tracer)
    assert traces[0].to_jsonl() == traces[1].to_jsonl()
    names = {s.name for s in traces[0].spans}
    assert {"session.query", "extract.round", "engine.run"} <= names
    # scheduler coverage: tiny corpora may satisfy every execution need
    # from the sampling cache (no scheduler.round), but the sampling
    # chunks themselves are scheduler spans
    assert {s.kind for s in traces[0].spans} >= {"session", "scheduler",
                                                "extract", "engine", "query"}
    key = lambda r: tuple(sorted(r["_docs"].items()))  # noqa: E731
    on, off = results[0], results[2]
    assert sorted(map(key, on.rows)) == sorted(map(key, off.rows))
    for col in ("input_tokens", "output_tokens", "llm_calls", "extractions"):
        assert getattr(on.ledger, col) == getattr(off.ledger, col), col


def test_engine_stats_registry_backed(served_env):
    corpus, cfg, params = served_env
    sess, eng = _served_session(corpus, cfg, params, None)
    sess.submit(_uni_query()).result()
    assert eng.stats["prefill_tokens"] > 0
    assert eng.metrics.value("engine.prefill_tokens") == \
        eng.stats["prefill_tokens"]
    with pytest.raises(MetricsSchemaError):
        eng.stats["not_a_stat"] += 1
    eng.metrics.check_complete("engine.")   # every schema key is reported


# ---------------------------------------------------------- EXPLAIN ANALYZE --


def test_report_requires_finished_query(wiki):
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki))
    h = sess.submit(_players_query())
    with pytest.raises(RuntimeError, match="in flight"):
        h.report()
    h.result()
    assert h.report()["qid"] == h.qid


def test_report_joins_estimates_with_actuals(wiki):
    tr = Tracer(clock="ticks", level=LEVEL_FULL)
    res, h = _traced_oracle_run(wiki, tr)
    rep = h.report()
    assert rep["rows"] == len(res.rows)
    assert rep["totals"]["input_tokens"] == res.ledger.input_tokens
    (table,) = rep["tables"]
    assert table["table"] == "players" and table["candidate_docs"] > 0
    stages = {st_["attr"]: st_ for st_ in table["stages"]}
    assert set(stages) == {"age", "all_stars"}
    for st_ in stages.values():
        assert st_["evaluated"] > 0
        assert 0.0 <= st_["actual_selectivity"] <= 1.0
        assert st_["est_selectivity"] is not None
        assert st_["invocations"] > 0
        assert st_["actual_tokens"] > 0
        assert st_["actual_tokens_per_call"] > 0
    # evaluation counts are internally consistent (escalation retries may
    # re-evaluate a filter, so `evaluated` can exceed the candidate count)
    for st_ in table["stages"]:
        assert st_["passed"] <= st_["evaluated"]
    assert rep["trace"]["clock"] == "ticks" and rep["trace"]["spans"] > 0
    text = h.report_text()
    assert "EXPLAIN ANALYZE" in text and "age" in text
    assert "est_sel" in text and "act_sel" in text


def test_report_per_attr_ledger_actuals(wiki):
    """Per-attr actuals account for every charge except the sampling
    phase, whose full-document prompts span all attrs (attr=None there —
    they report under per_phase['sampling'] instead)."""
    _, h = _traced_oracle_run(wiki, None)
    led = h.ledger
    assert led.per_attr and led.per_attr_calls
    sampling = led.per_phase.get("sampling", 0)
    assert sum(led.per_attr.values()) == \
        led.input_tokens + led.output_tokens - sampling
    assert 0 < sum(led.per_attr_calls.values()) <= led.llm_calls
