"""Session layer (DESIGN.md §11): query lifecycle + multi-query semantics.

Covers the API-redesign invariants:
  * validation at construction / prepare time (unknown op, foreign-table
    references, unknown table/attr names) — never mid-extraction;
  * no cross-query state leakage on one engine (per-query plan log, wall
    time, token columns; session ledger = sum of children);
  * sampling-investment reuse: a covered second query skips sampling;
  * concurrency invariance: N disjoint queries multiplexed through one
    Session produce rows and per-query ledger token columns identical to
    fresh serial engines (oracle + served paths);
  * streaming: `rows()` yields every row exactly once and agrees with
    `.result()`;
  * `explain()` estimates match the session's sample statistics.
"""
import pytest

from repro.core import (Engine, Filter, JoinEdge, Query, QueryError, Session,
                        conj, plan_expression)
from repro.data.corpus import Corpus, make_swde_corpus, make_wiki_corpus
from repro.extract import OracleExtractor
from repro.index.retriever import TwoLevelRetriever


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_corpus(seed=0)


def _row_key(r):
    return tuple(sorted(r["_docs"].items()))


def _assert_equivalent(res_a, res_b):
    assert sorted(map(_row_key, res_a.rows)) == sorted(map(_row_key, res_b.rows))
    led_a, led_b = res_a.ledger, res_b.ledger
    assert led_a.input_tokens == led_b.input_tokens
    assert led_a.output_tokens == led_b.output_tokens
    assert led_a.extractions == led_b.extractions
    assert led_a.per_phase == led_b.per_phase


def _players_query(age=30, stars=5):
    return Query(tables=["players"], select=[("players", "player_name")],
                 where=conj(Filter("age", ">", age, table="players"),
                            Filter("all_stars", ">=", stars, table="players")))


def _teams_query():
    return Query(tables=["teams"], select=[("teams", "location")],
                 where=Filter("championships", ">", 14, table="teams"))


def _owners_query():
    return Query(tables=["owners"], select=[("owners", "industry")],
                 where=Filter("net_worth", ">", 3.0, table="owners"))


# ------------------------------------------------------------- validation --


def test_filter_op_validated_at_construction():
    with pytest.raises(QueryError, match="unknown op"):
        Filter("age", "~=", 30)
    # the valid set still constructs
    for op in ("=", "!=", ">", ">=", "<", "<=", "between", "in", "contains"):
        Filter("age", op, 1, value2=2)


def test_query_rejects_foreign_table_references():
    with pytest.raises(QueryError, match="SELECT"):
        Query(tables=["players"], select=[("teams", "team_name")])
    with pytest.raises(QueryError, match="WHERE"):
        Query(tables=["players"], select=[("players", "player_name")],
              where=Filter("championships", ">", 1, table="teams"))
    with pytest.raises(QueryError, match="join"):
        Query(tables=["players"], select=[("players", "player_name")],
              joins=[JoinEdge("players", "team_name", "teams", "team_name")])
    with pytest.raises(QueryError, match="no tables"):
        Query(tables=[], select=[])


def test_prepare_rejects_unknown_names(wiki):
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki))
    with pytest.raises(QueryError, match="unknown table"):
        sess.prepare(Query(tables=["astronauts"],
                           select=[("astronauts", "name")]))
    with pytest.raises(QueryError, match="unknown SELECT attribute"):
        sess.prepare(Query(tables=["players"],
                           select=[("players", "shoe_size")]))
    with pytest.raises(QueryError, match="unknown WHERE attribute"):
        sess.prepare(Query(tables=["players"],
                           select=[("players", "player_name")],
                           where=Filter("shoe_size", ">", 10, table="players")))
    with pytest.raises(QueryError, match="unknown join attribute"):
        sess.prepare(Query(
            tables=["players", "teams"],
            select=[("players", "player_name")],
            joins=[JoinEdge("players", "player_name", "teams", "shoe_size")]))
    # validation never charges anything
    assert sess.ledger.total_tokens == 0
    # and a valid query passes
    sess.prepare(_players_query())


# ------------------------------------------- per-query state (satellite 1) --


def test_sequential_queries_no_state_leak(wiki):
    """Regression: `_plan_log` / wall time used to accumulate across
    `execute()` calls on one engine, so the second QueryResult reported the
    first query's plans and double-counted wall time."""
    eng = Engine(TwoLevelRetriever(wiki), OracleExtractor(wiki), batch_size=8)
    r1 = eng.execute(_players_query(30, 5))
    r2 = eng.execute(Query(tables=["players"],
                           select=[("players", "player_name")],
                           where=Filter("age", ">", 35, table="players")))
    # per-query plan logs: q2's log only holds q2 plans
    assert r2.plans_sampled
    for plan in r2.plans_sampled.values():
        assert "> 35" in plan and "all_stars" not in plan
    assert any("all_stars" in p for p in r1.plans_sampled.values())
    # per-query wall time sums to the session's, no double counting
    assert r1.ledger.wall_time_s > 0 and r2.ledger.wall_time_s > 0
    total = eng.ledger.wall_time_s
    assert r1.ledger.wall_time_s < total and r2.ledger.wall_time_s < total
    # the old bug double-counted (q2 reported q1's time too: sum ≈ 2x);
    # generous tolerance keeps this robust on noisy shared CPUs
    assert r1.ledger.wall_time_s + r2.ledger.wall_time_s \
        == pytest.approx(total, rel=0.2)
    # per-query token columns sum to the session ledger
    assert r1.ledger.total_tokens + r2.ledger.total_tokens \
        == eng.ledger.total_tokens
    # q2's attrs are covered by q1's sampling -> reused, sampling column 0
    assert r2.meta["sampling_reused"] == {"players": True}
    assert r2.ledger.per_phase.get("sampling", 0) == 0
    assert r1.ledger.per_phase["sampling"] > 0


# --------------------------------------------------- concurrency invariance --


def test_concurrent_disjoint_queries_match_fresh_engines(wiki):
    """N queries on disjoint tables multiplexed through one Session must
    produce rows and per-query token columns identical to the same queries
    run serially on fresh engines (the test_batching invariant, lifted to
    whole queries)."""
    queries = [_players_query(), _teams_query(), _owners_query()]
    serial = [Engine(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                     batch_size=8).execute(q) for q in queries]

    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    handles = [sess.submit(sess.prepare(q)) for q in queries]
    # drive via the *last* handle first: progress must not depend on which
    # handle the caller waits on
    results = [handles[-1].result()] and [h.result() for h in handles]
    for s, c in zip(serial, results):
        _assert_equivalent(s, c)
    assert not sess._active
    # the merged rounds stay within each query's sum (sharing never costs)
    assert sess.ledger.total_tokens == sum(r.ledger.total_tokens
                                           for r in results)


def test_concurrent_same_table_rows_match_serial_session(wiki):
    """Two queries on the SAME table: the second reuses the first's
    sampling investment. Concurrent submission must yield exactly the rows
    of serial submission through an identical session."""
    q1 = _players_query(30, 5)
    q2 = Query(tables=["players"], select=[("players", "player_name")],
               where=Filter("age", ">", 35, table="players"))

    serial = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                     batch_size=8)
    s1 = serial.execute(q1)
    s2 = serial.execute(q2)

    conc = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    h1, h2 = conc.submit(q1), conc.submit(q2)
    c2, c1 = h2.result(), h1.result()

    assert sorted(map(_row_key, s1.rows)) == sorted(map(_row_key, c1.rows))
    assert sorted(map(_row_key, s2.rows)) == sorted(map(_row_key, c2.rows))
    # in both sessions the second query skipped sampling (stats reuse);
    # under concurrency it *waited* for q1's sampling rather than re-paying
    for r in (s2, c2):
        assert r.meta["sampling_reused"] == {"players": True}
        assert r.ledger.per_phase.get("sampling", 0) == 0


# ---------------------------------------------------------------- streaming --


def test_rows_streams_each_row_exactly_once(wiki):
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    h = sess.submit(_players_query())
    it = h.rows()
    first = next(it)
    streamed = [first] + list(it)
    res = h.result()
    assert streamed == res.rows
    assert len({_row_key(r) for r in streamed}) == len(streamed)
    # a fresh iterator replays the same rows (it never mutates the result)
    assert list(h.rows()) == res.rows


@pytest.mark.parametrize("queue_depth", [1, 2, 16])
def test_small_queue_depth_never_stalls(wiki, queue_depth):
    """Regression: when an entire admitted wave of document coroutines
    resolves from the session cache (no extraction needs), the run queue
    must re-admit the next wave instead of reporting a stalled round —
    with small queue_depth the sampled docs alone trigger this."""
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=4, queue_depth=queue_depth)
    r1 = sess.execute(_players_query())
    assert r1.rows
    # second covered query runs almost entirely from cache — the extreme
    # all-cached-wave case
    r2 = sess.execute(Query(tables=["players"],
                            select=[("players", "player_name")],
                            where=Filter("age", ">", 35, table="players")))
    assert r2.rows and r2.meta["sampling_reused"] == {"players": True}


def test_rows_streams_before_completion(wiki):
    """With batch_size=1 projection streams row by row: the first row must
    arrive while the query is still in flight (documents still projecting)."""
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=1)
    h = sess.submit(_players_query())
    it = h.rows()
    first = next(it)
    assert first is not None and not h.done
    rest = list(it)
    assert h.done and [first] + rest == h.result().rows


# ------------------------------------------------------------------ explain --


def test_explain_reports_sample_stat_estimates(wiki):
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    sess.execute(_players_query(30, 5))       # pays the sampling investment
    stats = sess._samples["players"].stats

    q = _players_query(35, 8)
    prep = sess.prepare(q)
    ex = prep.explain()
    tbl = ex["tables"][0]
    assert tbl["table"] == "players"
    assert tbl["sampling"] == {"reused": True, "n_sampled": stats.n_sampled}
    f_age = Filter("age", ">", 35, table="players")
    f_stars = Filter("all_stars", ">=", 8, table="players")
    by_attr = {s["attr"]: s for s in tbl["stages"]}
    assert by_attr["age"]["selectivity"] == round(stats.selectivity(f_age), 4)
    assert by_attr["all_stars"]["selectivity"] == \
        round(stats.selectivity(f_stars), 4)
    assert by_attr["age"]["mean_cost_tokens"] == round(stats.mean_cost("age"), 2)
    plan = plan_expression(q.where, lambda f: stats.mean_cost(f.attr),
                           stats.selectivity)
    assert tbl["est_cost_tokens_per_doc"] == round(plan.cost, 2)
    assert tbl["est_pass_rate"] == round(plan.prob, 4)
    assert [s["filter"] for s in tbl["stages"]] == \
        [str(f) for f in plan.ordered_filters()]
    # unsampled table -> default estimates, planned sample size reported
    ex2 = sess.prepare(_teams_query()).explain()
    assert ex2["tables"][0]["sampling"]["reused"] is False
    assert ex2["tables"][0]["sampling"]["planned_sample"] > 0
    assert ex2["tables"][0]["stages"][0]["selectivity"] == 0.5
    # rendering works and names the key facts
    text = prep.explain_text()
    assert "players" in text and "sel=" in text


def test_uncovered_resample_widens_coverage(wiki):
    """An uncovered query re-samples the UNION of its attrs and the prior
    sample's, so a third query covered by the original investment never
    re-pays (coverage only grows)."""
    sess = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    sess.execute(Query(tables=["players"],
                       select=[("players", "player_name")],
                       where=Filter("age", ">", 35, table="players")))
    # uncovered: all_stars was never sampled -> re-sample, widened
    r2 = sess.execute(Query(tables=["players"],
                            select=[("players", "player_name")],
                            where=Filter("all_stars", ">=", 10,
                                         table="players")))
    assert r2.meta["sampling_reused"] == {"players": False}
    assert {"age", "all_stars", "player_name"} \
        <= set(sess._samples["players"].attrs)
    # covered by the ORIGINAL attrs: still free after the replacement
    r3 = sess.execute(Query(tables=["players"],
                            select=[("players", "player_name")],
                            where=Filter("age", ">", 38, table="players")))
    assert r3.meta["sampling_reused"] == {"players": True}
    assert r3.ledger.per_phase.get("sampling", 0) == 0


def test_concurrent_uncovered_resample_waits_for_quiet_table(wiki):
    """An uncovered query must not re-sample (mutating shared thresholds /
    evidence / cache) while another query is mid-flight on the table: it
    waits, so concurrent submission yields exactly the serial-session
    rows."""
    q1 = _players_query(30, 5)                      # attrs {age, all_stars, player_name}
    q2 = Query(tables=["players"], select=[("players", "player_name")],
               where=Filter("ppg", ">", 12.0, table="players"))  # uncovered

    serial = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                     batch_size=8)
    s1, s2 = serial.execute(q1), serial.execute(q2)

    conc = Session(TwoLevelRetriever(wiki), OracleExtractor(wiki),
                   batch_size=8)
    h1, h2 = conc.submit(q1), conc.submit(q2)
    c2, c1 = h2.result(), h1.result()

    assert sorted(map(_row_key, s1.rows)) == sorted(map(_row_key, c1.rows))
    assert sorted(map(_row_key, s2.rows)) == sorted(map(_row_key, c2.rows))
    for r in (s2, c2):
        assert r.meta["sampling_reused"] == {"players": False}


# ------------------------------------------- escalation + failure isolation --


class _StubRetriever:
    """Minimal duck-typed retriever: every doc has one 5-token segment per
    attribute; no thresholds, no evidence."""

    def __init__(self, corpus):
        self.corpus = corpus

    def candidate_docs(self, table, attrs):
        return sorted(self.corpus.tables[table])

    refine_candidates = candidate_docs

    def segments(self, doc_id, attr, table=None):
        return [f"{attr} segment of {doc_id}"]

    def segment_tokens(self, doc_id, attr, table=None):
        return 5

    def add_evidence(self, table, attr, segments, doc_id=None):
        pass

    def finalize_thresholds(self, table, attrs, stats):
        pass


class _StubExtractor:
    """Segment-scoped extraction of `flaky` attrs returns None (present but
    unparseable); the full-document escalation prompt recovers the truth.
    Counts escalations per key to verify single-charge semantics."""

    def __init__(self, corpus, flaky):
        self.corpus = corpus
        self.flaky = set(flaky)
        self.escalations = []

    def extract_batch(self, items):
        out = []
        for doc_id, attr, segs in items:
            full_doc = segs == [self.corpus.docs[doc_id].text]
            if full_doc:
                self.escalations.append((doc_id, attr))
            value = (self.corpus.docs[doc_id].truth[attr]
                     if (full_doc or attr not in self.flaky) else None)
            out.append((value, 5))
        return out

    def extract_full_doc_batch(self, items):
        res = []
        for doc_id, attrs in items:
            truth = self.corpus.docs[doc_id].truth
            vals = {a: (None if a in self.flaky else truth[a]) for a in attrs}
            res.append((vals, {}, 10))
        return res


def _stub_world():
    from repro.data.corpus import AttrSpec, Document
    docs, specs = {}, {"x": AttrSpec("x", "int", "x value", [], r"x=(\d+)"),
                       "name": AttrSpec("name", "str", "the name", [],
                                        r"name=(\w+)")}
    for i in range(4):
        d = f"t/{i}"
        docs[d] = Document(d, "t", f"document {i}",
                           truth={"x": i + 1, "name": f"N{i}"})
    corpus = Corpus("stub", docs, {"t": sorted(docs)}, {"t": specs},
                    {"t": "t"})
    return corpus


def test_concurrent_escalation_shares_one_retry_and_drops_no_rows():
    """Regression: two concurrent queries SELECTing the same output-critical
    attribute whose segment extraction fails must each keep their rows —
    the same-round escalation is shared (first owner pays), not skipped by
    whichever query is pumped second."""
    corpus = _stub_world()
    sess = Session(_StubRetriever(corpus),
                   _StubExtractor(corpus, flaky={"name"}), batch_size=4)
    q1 = Query(tables=["t"], select=[("t", "name")],
               where=Filter("x", ">", 0, table="t"))
    q2 = Query(tables=["t"], select=[("t", "name")],
               where=Filter("x", ">", 1, table="t"))
    h1, h2 = sess.submit(q1), sess.submit(q2)
    r1, r2 = h1.result(), h2.result()
    assert sorted(r["t.name"] for r in r1.rows) == ["N0", "N1", "N2", "N3"]
    assert sorted(r["t.name"] for r in r2.rows) == ["N1", "N2", "N3"]
    # one full-doc retry per key across BOTH queries
    esc = sess.extractor.escalations
    assert len(esc) == len(set(esc)) == 4


def test_coroutine_failure_isolated_to_its_query(wiki):
    """A query whose document coroutine raises fails only its own handle;
    concurrent queries on the same session complete normally."""

    class _Poisoned(TwoLevelRetriever):
        def segment_tokens(self, doc_id, attr, table=None):
            if attr == "championships":
                raise RuntimeError("index shard offline")
            return super().segment_tokens(doc_id, attr, table)

    sess = Session(_Poisoned(wiki), OracleExtractor(wiki), batch_size=8)
    good, bad = sess.submit(_players_query()), sess.submit(_teams_query())
    with pytest.raises(RuntimeError, match="index shard offline"):
        bad.result()
    res = good.result()
    assert res.rows and not sess._active
    # the failed handle's sampling reservation was released
    assert not bad.reservations


# ------------------------------------------------------------- served path --


def _mini_swde(n_per_table=8):
    full = make_swde_corpus()
    uni = [d for d in sorted(full.docs) if "universities" in d][:n_per_table]
    lap = [d for d in sorted(full.docs) if "laptops" in d][:n_per_table]
    return full.subset(uni + lap)


def test_served_concurrent_queries_match_fresh_engines():
    """Concurrency invariance on the REAL serving engine: two disjoint
    queries multiplexed over one engine produce the same rows and token
    columns as fresh serial engines, in fewer or equal engine runs."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data import lm_data
    from repro.extract.served import ServedExtractor
    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    corpus = _mini_swde()
    cfg = get_smoke_config("qwen2.5-3b").replace(vocab_size=lm_data.VOCAB)
    params = init_params(cfg, jax.random.PRNGKey(0))
    qa = Query(tables=["universities"],
               select=[("universities", "university_name")],
               where=Filter("tuition", "<", 30000, table="universities"))
    qb = Query(tables=["laptops"], select=[("laptops", "model_name")],
               where=Filter("ram_gb", ">=", 16, table="laptops"))

    def fresh(q):
        eng = ServingEngine(cfg, params, slots=4, max_len=1024,
                            prefix_cache=True)
        e = Engine(TwoLevelRetriever(corpus),
                   ServedExtractor(corpus, eng, max_new=6), batch_size=4)
        return e.execute(q), eng.stats["runs"]

    ra, runs_a = fresh(qa)
    rb, runs_b = fresh(qb)

    eng = ServingEngine(cfg, params, slots=4, max_len=1024, prefix_cache=True)
    sess = Session(TwoLevelRetriever(corpus),
                   ServedExtractor(corpus, eng, max_new=6), batch_size=4)
    ha, hb = sess.submit(qa), sess.submit(qb)
    res_a, res_b = ha.result(), hb.result()

    _assert_equivalent(ra, res_a)
    _assert_equivalent(rb, res_b)
    # multiplexing shares rounds; it must never need more engine runs
    assert eng.stats["runs"] <= runs_a + runs_b
